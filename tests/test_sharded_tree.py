"""Sharded hub BEHIND the forwarding tree (paper §6 item 4 composed with
§4): the top-level tree node routes the Table-2 verbs by task hash to
per-shard TaskServers.  Covers the full engine lifecycle suite over
`transport="tree", shards>1` — seeded worker kills (announced + silent),
stragglers, cross-shard poison through the relay, CompleteSteal
split/merge when the finished-batch and steal-target shards differ,
pruning under the tree, and the futures client riding the composed
configuration."""
import pytest

from repro.core.dwork import run_pool
from repro.core.dwork.api import CompleteSteal, ExitResp, Steal, TaskMsg
from repro.core.dwork.sharded import ShardedHub
from repro.core.engine import (COMPLETED, RPC, STOLEN, Engine, FaultPlan,
                               ManualClock)


def flat_tree_engine(n, *, workers=4, shards=4, steal_n=4, **kw):
    eng = Engine(workers=workers, transport="tree", shards=shards,
                 steal_n=steal_n, **kw)
    for i in range(n):
        eng.submit(f"t{i}", fn=lambda: None)
    return eng


def name_on_shard(hub, shard, prefix):
    """Probe names until one hashes to `shard` (str hashing is seeded per
    process, so shard homes are discovered at runtime, not assumed)."""
    return next(f"{prefix}{i}" for i in range(1000)
                if hub._shard_of(f"{prefix}{i}") == shard)


# ------------------------------------------------------------ lifecycle


def test_sharded_tree_dag_execution_values():
    eng = Engine(workers=2, transport="tree", shards=2, steal_n=2)
    eng.submit("a", fn=lambda: 1)
    eng.submit("b", fn=lambda: 2, deps=["a"])
    eng.submit("c", fn=lambda: 3, deps=["a", "b"])
    rep = eng.run()
    assert rep.completed == {"a", "b", "c"} and not rep.stalled
    assert rep.results["c"].value == 3


def test_sharded_tree_all_shards_served():
    rep = flat_tree_engine(200).run()
    assert len(rep.completed) == 200 and not rep.stalled
    assert rep.backend_stats["tree"]["shards"] == 4
    per_shard = rep.backend_stats["shards"]
    assert len(per_shard) == 4
    # hash routing + affinity stealing actually spread the load
    assert all(s["completed"] > 0 for s in per_shard)
    assert sum(s["completed"] for s in per_shard) >= 200


def test_sharded_tree_hop_attribution_per_shard_not_double_counted():
    rep = flat_tree_engine(100, shards=2, workers=4).run()
    ov = rep.overhead()
    assert "hop:L1:s0" in ov.rpc_by_op and "hop:L1:s1" in ov.rpc_by_op
    # per-shard hops are attribution-only: excluded from the end-to-end
    # rpc totals exactly like plain forwarder hops
    hop_n = sum(c for op, (c, _t) in ov.rpc_by_op.items()
                if op.startswith("hop:"))
    total_n = sum(c for c, _t in ov.rpc_by_op.values())
    assert ov.n_rpc == total_n - hop_n
    assert hop_n > 0


def test_two_level_sharded_tree_routes_at_the_apex():
    """Leaf forwarders blind-relay, the level-1 routers hash-route: both
    hop flavors appear, and the composed run completes."""
    eng = flat_tree_engine(60, workers=8, shards=2, tree_fanout=2,
                           tree_levels=2)
    rep = eng.run()
    assert len(rep.completed) == 60 and not rep.stalled
    assert rep.backend_stats["tree"]["forwarders"] == [2, 4]
    ops = set(rep.overhead().rpc_by_op)
    assert "hop:L2" in ops                       # leaf relays
    assert {"hop:L1:s0", "hop:L1:s1"} <= ops     # apex shard fan-out
    assert "hop:L1" not in ops                   # routers replace blind L1


def test_sharded_tree_trace_counts_conserved():
    rep = flat_tree_engine(80, shards=2, workers=2, steal_n=2).run()
    tr = rep.trace
    assert tr.count(COMPLETED) == 80
    assert tr.count(STOLEN) >= 80
    assert tr.count(RPC) > 0


# ------------------------------------------------------------ fault paths


def test_sharded_tree_announced_kill_zero_lost_tasks():
    faults = FaultPlan(seed=7).kill_worker("w1", after_steals=4)
    eng = flat_tree_engine(120, workers=3, shards=4, faults=faults)
    rep = eng.run()
    assert not rep.stalled
    assert len(rep.completed) == 120             # zero lost tasks
    assert rep.overhead().n_requeued >= 1
    assert rep.backend_stats["completed"] >= 120
    # nothing stuck leased on ANY shard after the recovery
    assert all(s["assigned"] == 0 for s in rep.backend_stats["shards"])


def test_sharded_tree_kill_mid_complete_steal_split_shards():
    """Worker death while its finished batch and its steal target sit on
    DIFFERENT shards: with affinity stealing a worker drains its home
    shard, then its next CompleteSteal carries home-shard completions
    while the steal is served by another shard (the split/merge path).
    The kill must still lose zero tasks and leave no stale leases."""
    faults = FaultPlan(seed=11).kill_worker("w0", after_steals=8)
    eng = flat_tree_engine(80, workers=2, shards=2, steal_n=4,
                           faults=faults)
    rep = eng.run()
    assert not rep.stalled
    assert len(rep.completed) == 80
    assert rep.overhead().n_requeued >= 1
    assert all(s["assigned"] == 0 for s in rep.backend_stats["shards"])
    # both shards actually saw traffic through the router
    ov = rep.overhead()
    assert "hop:L1:s0" in ov.rpc_by_op and "hop:L1:s1" in ov.rpc_by_op


def test_sharded_tree_silent_death_recovered_by_lease():
    clk = ManualClock(tick=1e-3)
    faults = FaultPlan(seed=3).kill_worker("w1", after_steals=2, silent=True)
    eng = Engine(workers=2, transport="tree", shards=2, steal_n=2,
                 clock=clk, lease_timeout=0.05, faults=faults)
    for i in range(20):
        eng.submit(f"x{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 20 and not rep.stalled
    assert rep.overhead().n_requeued >= 1


def test_sharded_tree_straggler_jitter_recorded():
    faults = FaultPlan(seed=11).stragglers(1e-3)
    eng = Engine(workers=2, transport="tree", shards=2, steal_n=2,
                 faults=faults)
    for i in range(16):
        eng.submit(f"j{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 16
    assert rep.overhead().virtual_s != 0.0


def test_sharded_tree_cross_shard_poison_through_relay():
    """A producer failing on its home shard must poison a dependent homed
    on ANOTHER shard even though every verb crossed the relay: the
    poisoned `__notify__` can never Release the dependent's held proxy,
    so the hub's propagation must fail the proxy (and the dependent)
    instead of letting them dangle."""
    hub = ShardedHub(2)
    prod = name_on_shard(hub, 0, "prod")
    dep = name_on_shard(hub, 1, "dep")
    hub.create(prod)
    hub.create(dep, deps=[prod])
    hub.create(name_on_shard(hub, 1, "bystander"))
    rep = run_pool(hub, lambda name, meta: name != prod,
                   workers=2, steal_n=2, transport="tree", tree_fanout=2)
    assert not rep.stalled
    assert prod in rep.errors and dep in rep.errors
    assert len(rep.completed) == 1               # the bystander ran
    # the held proxy reached a terminal state too — nothing dangles
    assert all(len(s.ready) == 0 for s in hub.shards)


def test_sharded_tree_cancel_rides_the_boss_link():
    """Cancel is worker-less, so it crosses the boss link into a router:
    an unleased dep-waiting task is withdrawn on its home shard and its
    cross-shard dependents are poisoned."""
    eng = Engine(workers=2, transport="tree", shards=2, steal_n=2)
    eng.submit("root", fn=lambda: None)
    eng.submit("victim", fn=lambda: None, deps=["root"])
    eng.submit("heir", fn=lambda: None, deps=["victim"])
    assert eng.cancel("victim") is True          # unleased: dep-waiting
    rep = eng.run()
    assert not rep.stalled
    assert rep.completed == {"root"}
    assert "victim" in rep.errors and "heir" in rep.errors


def test_sharded_tree_prune_under_the_tree():
    """prune_terminal reaches every shard behind the tree (home-map
    cleanup included) and the session keeps working afterwards."""
    from repro.client import Client

    with Client(scheduler="dwork", workers=2, transport="tree",
                shards=2) as c:
        xs = c.gather([c.submit(lambda v: v + 1, i) for i in range(30)])
        assert xs == [i + 1 for i in range(30)]
        hub = c.engine.backend.hub
        before = sum(len(s.joins) for s in hub.shards)
        assert c.prune() > 0
        assert sum(len(s.joins) for s in hub.shards) < before
        assert len(hub.home) < before            # home map pruned too
        # single-use names: new work is unaffected by the prune
        assert c.submit(lambda: 99).result(timeout=30) == 99


# ------------------------------------------ CompleteSteal split/merge unit


def recording_hub(n_shards=2):
    hub = ShardedHub(n_shards)
    sent = []

    def sender(shard, msg):
        sent.append((shard, msg))
        return hub.shards[shard].handle(msg)

    hub.sender = sender
    return hub, sent


def test_complete_steal_merges_target_shard_batch_onto_steal_frame():
    """Completions homed on the steal-target shard ride the SAME
    CompleteSteal frame as the steal (one per-shard round-trip)."""
    hub, sent = recording_hub(2)
    a = name_on_shard(hub, 0, "a")
    b = name_on_shard(hub, 0, "b")
    hub.create(a)
    hub.create(b)
    r, shard = hub.steal("w0", n=1, affinity=0)
    assert isinstance(r, TaskMsg) and shard == 0
    sent.clear()
    r, shard = hub.complete_steal("w0", [(a, True, 0)], n=1, affinity=0)
    assert isinstance(r, TaskMsg) and [t for t, _ in r.tasks] == [b]
    merged = [(s, m) for s, m in sent if isinstance(m, CompleteSteal)]
    assert len(merged) == 1 and merged[0][0] == 0
    assert merged[0][1].done == [(a, True)] and merged[0][1].n == 1
    # no separate complete-only frame was sent anywhere
    assert not any(isinstance(m, CompleteSteal) and m.n == 0
                   for _s, m in sent)


def test_complete_steal_splits_batches_across_differing_shards():
    """Finished batch homed on shard 0, steal served by shard 1 (shard 0
    exhausted): the verb is SPLIT — a complete-only CompleteSteal to the
    home shard, the steal probing on to the other shard."""
    hub, sent = recording_hub(2)
    a = name_on_shard(hub, 0, "a")
    c = name_on_shard(hub, 1, "c")
    hub.create(a)
    hub.create(c)
    r, shard = hub.steal("w0", n=1, affinity=0)
    assert isinstance(r, TaskMsg) and shard == 0     # a, from shard 0
    sent.clear()
    r, shard = hub.complete_steal("w0", [(a, True, 0)], n=1, affinity=0)
    assert isinstance(r, TaskMsg) and shard == 1     # c, from shard 1
    # shard 0 got the merged frame (completions + steal attempt),
    # shard 1 served the steal itself: split across shards, and the
    # home-shard completions were applied before the cross-shard steal
    frames = [(s, type(m).__name__) for s, m in sent]
    assert frames[0] == (0, "CompleteSteal")
    assert (1, "Steal") in frames
    assert a in hub.shards[0].completed


def test_complete_steal_with_failures_applies_before_steal_and_poisons():
    """A failed completion never merges onto the steal frame: it is
    applied (and its cross-shard poison propagated) BEFORE more work is
    handed out."""
    hub, sent = recording_hub(2)
    prod = name_on_shard(hub, 0, "p")
    dep = name_on_shard(hub, 1, "d")
    hub.create(prod)
    hub.create(dep, deps=[prod])
    r, shard = hub.steal("w0", n=1, affinity=0)
    assert isinstance(r, TaskMsg) and shard == 0
    sent.clear()
    r, _shard = hub.complete_steal("w0", [(prod, False, 0)], n=1,
                                   affinity=0)
    assert isinstance(r, ExitResp)                   # everything terminal
    first = sent[0]
    assert first[0] == 0 and isinstance(first[1], CompleteSteal)
    assert first[1].n == 0                           # complete-only split
    assert prod in hub.shards[0].errors
    assert dep in hub.shards[1].errors               # poison crossed shards


def test_wire_handle_round_trips_the_relay_encoding():
    """`ShardedHub.handle` accepts the verbs exactly as a router decodes
    them from the wire — including msgpack's tuples->lists mangling."""
    from repro.core.dwork.api import decode, encode

    hub = ShardedHub(2)
    a = name_on_shard(hub, 0, "a")
    b = name_on_shard(hub, 1, "b")
    hub.create(a)
    hub.create(b, deps=[a])                          # cross-shard dep
    resp = hub.handle(decode(encode(Steal(worker="w0", n=2))))
    assert isinstance(resp, TaskMsg)
    got = [t for t, _m in resp.tasks]
    assert got == [a]                                # b still dep-waiting
    msg = decode(encode(CompleteSteal(worker="w0", done=[(a, True)], n=2)))
    resp = hub.handle(msg)
    assert isinstance(resp, TaskMsg)
    assert [t for t, _m in resp.tasks] == [b]        # released via notify
    assert isinstance(hub.handle(CompleteSteal(worker="w0",
                                               done=[(b, True)], n=0)),
                      ExitResp)
    assert hub.handle(Steal(worker="w0", n=1)).__class__ is ExitResp


# --------------------------------------------------------- futures client


def test_client_futures_chain_across_kill_on_sharded_tree():
    """The futures front door over the composed configuration: a chain of
    dependent futures survives a seeded worker kill with exactly-once
    resolution."""
    from repro.client import Client

    faults = FaultPlan(seed=9).kill_worker("w1", after_steals=6)
    resolved = []
    with Client(scheduler="dwork", workers=3, transport="tree", shards=4,
                faults=faults) as c:
        fs = [c.submit(lambda x: x * x, i) for i in range(40)]
        head = c.submit(lambda: 1)
        chain = head
        for _ in range(5):
            chain = c.submit(lambda v: v + 1, chain)
        for f in fs:
            f.add_done_callback(lambda f: resolved.append(f.name))
        assert c.gather(fs) == [i * i for i in range(40)]
        assert chain.result(timeout=60) == 6
    assert sorted(resolved) == sorted({f.name for f in fs})   # exactly once


def test_run_pool_sharded_hub_tree_matches_inproc_results():
    hub = ShardedHub(2)
    for i in range(50):
        hub.create(f"t{i}", meta={"x": i})
    rep = run_pool(hub, lambda name, meta: (True, meta["x"] * 2),
                   workers=4, steal_n=4, transport="tree", tree_fanout=2)
    assert len(rep.completed) == 50 and not rep.stalled
    assert all(rep.results[f"t{i}"].value == 2 * i for i in range(50))
    assert rep.backend_stats["tree"]["shards"] == 2
    assert any(op.startswith("hop:L1:s")
               for op in rep.overhead().rpc_by_op)
    # the tree hands the hub back on teardown: a caller-supplied hub
    # stays usable in-process (sender reset, not left on dead links)
    assert hub.sender is None
    hub.create("after_tree")
    r, _shard = hub.steal("w0", n=1)
    assert [t for t, _m in r.tasks] == ["after_tree"]


def test_engine_shards_attribute_reflects_backend():
    eng = Engine(workers=2, transport="tree", shards=3)
    try:
        assert eng.shards == 3
        assert eng.backend.n_shards == 3
    finally:
        eng.backend.close()
    eng = Engine(workers=2, transport="inproc")
    assert eng.shards == 1
