"""Sharding-rule tests: every model-axis-sharded parameter dim must divide
the production model-axis width (16) for EVERY assigned architecture —
the invariant the multi-pod dry-run depends on."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models.model import build_model
from repro.runtime.sharding import (batch_specs, cache_specs,
                                    effective_batch_axes, param_specs)

MODEL_AXIS = 16
DATA_AXIS = 16


@pytest.mark.parametrize("name", ARCH_IDS)
def test_param_specs_divisible(name):
    cfg = get_config(name)
    model = build_model(cfg)
    abstract = model.init_abstract()
    specs = param_specs(abstract, cfg)
    flat_a = jax.tree_util.tree_flatten_with_path(abstract)[0]
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_a) == len(flat_s)
    n_model_sharded = 0
    for (path, leaf), spec in zip(flat_a, flat_s):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, ax in enumerate(entries):
            if ax == "model":
                n_model_sharded += 1
                assert leaf.shape[dim] % MODEL_AXIS == 0, (
                    jax.tree_util.keystr(path), leaf.shape, dim)
    assert n_model_sharded > 0, "nothing TP-sharded"


@pytest.mark.parametrize("name", ARCH_IDS)
def test_cache_specs_divisible(name):
    cfg = get_config(name)
    model = build_model(cfg)
    mesh_axes = {"data": DATA_AXIS, "model": MODEL_AXIS}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = mesh_axes

    for shape_name in ("decode_32k", "long_500k"):
        sh = SHAPES[shape_name]
        cache = model.init_cache(sh.global_batch, sh.seq_len, abstract=True)
        specs = cache_specs(cfg, cache, FakeMesh(),
                            global_batch=sh.global_batch,
                            seq_shard_kv=(shape_name == "long_500k"))
        flat_c = jax.tree_util.tree_leaves(cache)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        for leaf, spec in zip(flat_c, flat_s):
            entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for dim, ax in enumerate(entries):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh_axes[a]
                assert leaf.shape[dim] % size == 0, (name, shape_name,
                                                     leaf.shape, dim, ax)


def test_effective_batch_axes():
    class M:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert effective_batch_axes(M(), 256) == ("pod", "data")
    assert effective_batch_axes(M(), 32) == ("pod", "data")
    assert effective_batch_axes(M(), 16) == ("data",)
    assert effective_batch_axes(M(), 1) is None


@pytest.mark.parametrize("name", ["qwen2.5-32b", "rwkv6-1.6b",
                                  "whisper-base", "qwen2-vl-2b"])
def test_batch_specs_cover_inputs(name):
    cfg = get_config(name)

    class M:
        axis_names = ("data", "model")
        shape = {"data": DATA_AXIS, "model": MODEL_AXIS}

    from repro.configs import input_specs
    for shape_name, sh in SHAPES.items():
        sp = batch_specs(cfg, sh, M())
        inputs = input_specs(cfg, sh)
        assert set(sp) == set(inputs), (shape_name, set(sp), set(inputs))
