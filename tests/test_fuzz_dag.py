"""Randomized DAG fuzz suite: seeded end-to-end workloads across every
transport, proving the scheduler + data plane against the properties
that matter — exact sink values, exactly-once future resolution, zero
task loss — under random fan-in/fan-out, payload sizes straddling the
inline threshold, seeded transient failures, and (proc) one mid-run
SIGKILL of a worker process.

Everything derives from `random.Random(seed)`, so a failure replays
deterministically: the assertion message (and a printed banner) carries
the exact `REPRO_FUZZ_SEEDS=<seed>` + transport + shards needed to
reproduce it.  Seeds come from the `REPRO_FUZZ_SEEDS` env var
(comma-separated; CI pins three).  The full matrix is `slow`; two small
smoke cases run in tier-1.

Task callables are built as closures (cloudpickle ships them by value,
so proc workers never need to import this module); transient failures
use first-run marker files, which work across process boundaries."""
import collections
import hashlib
import os
import random
import signal
import time

import pytest

from repro.client import Client
from repro.core.engine import FaultPlan, RetryPolicy

SEEDS = [int(s) for s in
         os.environ.get("REPRO_FUZZ_SEEDS", "7,23,101").split(",")]
HB = 0.1
INLINE = 2048                 # small threshold: sizes straddle it cheaply
N_TASKS = 60
FAIL_RATE = 0.12              # seeded fraction of tasks failing once
MATRIX = [("inproc", 1), ("inproc", 4), ("thread", 1), ("thread", 4),
          ("proc", 1), ("proc", 4)]


def _gen_dag(rng: random.Random, n: int) -> list:
    """-> [(deps, size, fail_once)] per task: random fan-in from earlier
    layers (fan-out emerges from reuse), sizes spanning tiny inlined
    values to several multiples of the inline threshold."""
    sizes = (8, 200, INLINE // 2, INLINE + 512, INLINE * 4)
    specs = []
    for i in range(n):
        deps = []
        if i and rng.random() < 0.7:
            deps = sorted(rng.sample(range(i), rng.randint(1, min(3, i))))
        specs.append((deps, rng.choice(sizes), rng.random() < FAIL_RATE))
    return specs


def _expected_values(specs: list) -> list:
    """Model the DAG locally: task i's value is digest-derived bytes of
    its spec'd size, folding in the first 16 bytes of each dep value —
    any corruption or misrouting anywhere changes a sink digest."""
    vals: list = []
    for i, (deps, size, _fail) in enumerate(specs):
        h = hashlib.md5(f"task{i}".encode())
        for d in deps:
            h.update(vals[d][:16])
        vals.append((h.digest() * (size // 16 + 1))[:size])
    return vals


def _run_case(transport: str, shards: int, seed: int, tmp_path) -> None:
    rng = random.Random(seed)
    specs = _gen_dag(rng, N_TASKS)
    expected = _expected_values(specs)
    ctx = (f"REPRO_FUZZ_SEEDS={seed} transport={transport} "
           f"shards={shards}")

    def make_fn(i, size, marker, pause):
        # closure, not a module-level def: cloudpickle ships it by value
        def fn(*dep_vals):
            if marker is not None and not os.path.exists(marker):
                open(marker, "w").close()
                raise RuntimeError(f"transient-{os.path.basename(marker)}")
            if pause:
                time.sleep(pause)
            h = hashlib.md5(f"task{i}".encode())
            for d in dep_vals:
                h.update(d[:16])
            return (h.digest() * (size // 16 + 1))[:size]
        return fn

    faults = None
    if transport == "thread":
        # mid-run kill, thread flavor: the injected-fault worker death
        faults = FaultPlan(seed).kill_worker(
            "w1", after_steals=max(N_TASKS // 6, 2))
    c = Client(transport=transport, workers=4, shards=shards,
               heartbeat_s=HB, inline_bytes=INLINE, faults=faults,
               retry=RetryPolicy(max_attempts=3, backoff=0.0, seed=seed))
    try:
        futs = []
        resolutions: collections.Counter = collections.Counter()
        for i, (deps, size, fail_once) in enumerate(specs):
            marker = (str(tmp_path / f"fail-{seed}-{i}") if fail_once
                      else None)
            pause = 0.004 if (transport == "proc" and rng.random() < 0.5) \
                else 0.0
            f = c.submit(make_fn(i, size, marker, pause),
                         *[futs[d] for d in deps], key=f"fz{i}")
            f.add_done_callback(
                lambda fut: resolutions.update([fut.name]))
            futs.append(f)
        if transport == "proc":
            # one mid-run SIGKILL: wait for some progress, then kill a
            # real worker process — requeue + (if it held the only copy
            # of a big value) the lost-value recompute must absorb it
            c._ensure_running()
            deadline = time.monotonic() + 30
            while sum(1 for f in futs if f.done()) < N_TASKS // 6 \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
            pids = list(c.engine.worker_pids().values())
            if pids:
                os.kill(rng.choice(pids), signal.SIGKILL)
        values = c.gather(futs, timeout=120)
        # ---- exact sink values (transitively checks every task)
        for i, (got, want) in enumerate(zip(values, expected)):
            assert got == want, \
                f"[{ctx}] task fz{i} value corrupted " \
                f"(len {len(got)} vs {len(want)})"
        # ---- exactly-once resolution
        multi = {n: k for n, k in resolutions.items() if k != 1}
        assert not multi, f"[{ctx}] futures resolved != once: {multi}"
        assert len(resolutions) == N_TASKS, \
            f"[{ctx}] task loss: {N_TASKS - len(resolutions)} futures " \
            "never resolved"
        # ---- transient failures really happened and were absorbed.
        # proc's mid-run SIGKILL can eat unreported first-run failures
        # (the rerun then sees the marker and succeeds without a retry
        # charge), so allow one worker's unreported batch of slack there
        n_transient = sum(1 for _, _, f in specs if f)
        min_retries = (max(n_transient - 4, 1) if transport == "proc"
                       else n_transient)
        if n_transient:
            assert c.engine.retries_total >= min_retries, \
                f"[{ctx}] expected >= {min_retries} retries " \
                f"({n_transient} transient tasks), saw " \
                f"{c.engine.retries_total}"
        if transport == "proc":
            assert c.engine.xfer_lost_total == 0 or values is not None
    except Exception:
        print(f"\nFUZZ REPLAY: {ctx}")
        raise
    finally:
        c.close()


# tier-1 smoke: one seed, the two cheap extremes of the matrix
@pytest.mark.parametrize("transport,shards", [("inproc", 1), ("thread", 4)])
def test_fuzz_dag_smoke(transport, shards, tmp_path):
    _run_case(transport, shards, SEEDS[0], tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("transport,shards", MATRIX)
def test_fuzz_dag_matrix(transport, shards, seed, tmp_path):
    _run_case(transport, shards, seed, tmp_path)


@pytest.mark.slow
def test_fuzz_dag_deterministic_per_seed(tmp_path):
    """The generator itself is deterministic: same seed, same DAG —
    the replay contract the failure banner depends on."""
    s1 = _gen_dag(random.Random(42), N_TASKS)
    s2 = _gen_dag(random.Random(42), N_TASKS)
    assert s1 == s2
    assert _expected_values(s1) == _expected_values(s2)
    assert _gen_dag(random.Random(43), N_TASKS) != s1
