"""Futures client tests: the one-front-door API over the unified engine.

Covers the acceptance snippet for all three schedulers, dynamic
future-as-dependency DAGs, the failure taxonomy (original exception /
TaskFailed / DependencyFailed / CancelledError), cancel of a
not-yet-stolen task, result(timeout=) expiry, gather with mixed
failures, exactly-once resolution across a seeded worker kill, the
bounded-state hooks (trace ring buffer, terminal pruning,
keep_results), and the idempotent engine shutdown lifecycle."""
import threading
import time

import pytest

from repro.client import (CancelledError, Client, DependencyFailed, Future,
                          TaskFailed, as_completed)
from repro.core.dwork import InProcTransport, TaskServer
from repro.core.dwork import Client as DworkClient
from repro.core.engine import (CANCELLED, Engine, FaultPlan, TraceRecorder,
                               WorkerCrash)


# ------------------------------------------------- the acceptance snippet


@pytest.mark.parametrize("scheduler", ["dwork", "pmake", "mpi_list"])
def test_snippet_works_unmodified_for_every_scheduler(scheduler):
    xs = list(range(40))
    with Client(scheduler=scheduler) as c:
        fs = [c.submit(lambda x=x: x * x) for x in xs]
        assert c.gather(fs) == [x * x for x in xs]
        ov = c.report()
        assert ov.n_tasks == len(xs)
        assert ov.per_task_overhead_s >= 0.0


def test_future_as_dependency_builds_dynamic_dag():
    with Client(workers=2) as c:
        a = c.submit(lambda: 3)
        b = c.submit(lambda v: v + 4, a)          # positional lift
        d = c.submit(lambda v, w=0: v * w, a, w=b)  # kwarg lift
        tail = c.submit(sum, c.submit(lambda: [1, 2, 3]))
        assert d.result(10) == 21
        assert tail.result(10) == 6
        # deps were registered engine-side, not just resolved by luck
        assert c.engine.tasks[b.name].deps == (a.name,)


def test_map_and_ordering_only_deps():
    with Client(workers=4) as c:
        order = []
        first = c.submit(lambda: order.append("first"))
        fs = c.map(lambda x, y: x + y, range(10), range(10))
        gated = c.submit(lambda: order.append("second"), deps=[first])
        assert c.gather(fs) == [2 * i for i in range(10)]
        gated.result(10)
        assert order == ["first", "second"]


# ------------------------------------------------------- failure taxonomy


def test_original_exception_rethrown_and_poisoning_downstream():
    with Client(workers=2) as c:
        bad = c.submit(lambda: 1 / 0)
        down = c.submit(lambda v: v + 1, bad)
        deeper = c.submit(lambda v: v + 1, down)
        with pytest.raises(ZeroDivisionError):
            bad.result(10)
        assert isinstance(bad.exception(10), ZeroDivisionError)
        for f in (down, deeper):
            with pytest.raises(DependencyFailed):
                f.result(10)
        assert down.exception(10) is not None


def test_gather_mixed_failures():
    with Client(workers=2) as c:
        ok1 = c.submit(lambda: 1)
        bad = c.submit(lambda: (_ for _ in ()).throw(ValueError("boom")))
        ok2 = c.submit(lambda: 2)
        down = c.submit(lambda v: v, bad)
        fs = [ok1, bad, ok2, down]
        # default: every future resolves first, then the first error raises
        with pytest.raises(ValueError, match="boom"):
            c.gather(fs)
        out = c.gather(fs, return_exceptions=True)
        assert out[0] == 1 and out[2] == 2
        assert isinstance(out[1], ValueError)
        assert isinstance(out[3], DependencyFailed)


def test_submit_after_dependency_failed_fails_fast():
    with Client(workers=1) as c:
        bad = c.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            bad.result(10)
        late = c.submit(lambda v: v, bad)      # dynamic DAG, dep already dead
        with pytest.raises(DependencyFailed):
            late.result(10)


# ------------------------------------------------------------------ cancel


def test_cancel_not_yet_stolen_task():
    # the client is built but NOT started: submissions sit server-side,
    # so the cancel race is deterministic
    c = Client(workers=1)
    a = c.submit(lambda: 1)
    b = c.submit(lambda v: v + 1, a)
    down = c.submit(lambda v: v * 2, b)
    assert b.cancel() is True
    assert b.cancelled() and b.done()
    with pytest.raises(CancelledError):
        b.result(1)
    with pytest.raises(CancelledError):
        b.exception(1)
    assert c.engine.tracer.count(CANCELLED) == 1
    with c:
        assert a.result(10) == 1               # untouched sibling completes
        with pytest.raises(DependencyFailed):
            down.result(10)                    # cancelled dep poisons it
    # cancel after terminal state: refused
    assert a.cancel() is False
    assert b.cancel() is False


def test_cancel_running_or_done_task_returns_false():
    release = threading.Event()
    with Client(workers=1, transport="thread") as c:
        running = c.submit(release.wait, 5)
        deadline = time.monotonic() + 5
        while c.engine.backend.server.lease == {} \
                and time.monotonic() < deadline:
            time.sleep(0.001)                  # wait until it is stolen
        assert running.cancel() is False       # already leased
        release.set()
        assert running.result(10) is True


# ---------------------------------------------------------------- timeouts


def test_result_timeout_expiry():
    with Client(workers=1, transport="thread") as c:
        gate = threading.Event()
        slow = c.submit(gate.wait, 5)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            slow.result(timeout=0.05)
        assert time.monotonic() - t0 < 2.0
        assert not slow.done()
        gate.set()
        assert slow.result(10) is True


def test_as_completed_yields_in_completion_order_and_times_out():
    with Client(workers=1) as c:
        fs = [c.submit(lambda x=x: x) for x in range(10)]
        got = [f.result() for f in as_completed(fs, timeout=10)]
        assert sorted(got) == list(range(10))
    with Client(workers=1, transport="thread") as c:
        gate = threading.Event()
        blocked = c.submit(gate.wait, 5)
        with pytest.raises(TimeoutError):
            list(as_completed([blocked], timeout=0.05))
        gate.set()
        blocked.result(10)


# ------------------------------------------- exactly-once across a crash


def test_future_dep_chain_survives_seeded_worker_kill():
    faults = FaultPlan(seed=11).kill_worker("w1", after_steals=8)
    resolutions: dict[str, int] = {}
    with Client(workers=4, steal_n=4, faults=faults) as c:
        flat = [c.submit(lambda x=x: x * 3) for x in range(150)]
        head = c.submit(lambda: 0)
        chain = [head]
        for _ in range(15):
            chain.append(c.submit(lambda v: v + 1, chain[-1]))
        for f in flat + chain:
            f.add_done_callback(
                lambda fu: resolutions.__setitem__(
                    fu.name, resolutions.get(fu.name, 0) + 1))
        assert c.gather(flat) == [x * 3 for x in range(150)]
        assert chain[-1].result(30) == 15
        ov = c.report()
        assert ov.n_requeued > 0              # the kill actually happened
        assert ov.n_tasks == 150 + 16         # zero loss, no double count
    # every future resolved exactly once (callbacks fire per resolution)
    assert set(resolutions.values()) == {1}
    assert len(resolutions) == 150 + 16


# ----------------------------------------------------------- batch mode


def test_batch_mode_futures_and_report():
    c = Client(resident=False, workers=2, steal_n=2)
    fs = [c.submit(lambda x=x: x + 1) for x in range(30)]
    bad = c.submit(lambda: 1 / 0)
    down = c.submit(lambda v: v, bad)
    assert c.gather(fs) == [x + 1 for x in range(30)]   # gather runs it
    assert isinstance(bad.exception(), ZeroDivisionError)
    with pytest.raises(DependencyFailed):
        down.result()
    rep = c.run()                                       # cached report
    assert len(rep.completed) == 30
    c.close()


def test_run_pool_is_a_client_shim_with_unchanged_contract():
    srv = TaskServer()
    boss = DworkClient(InProcTransport(srv), "boss")
    for i in range(25):
        boss.create(f"t{i}", meta={"x": i})
    from repro.core.dwork import run_pool
    rep = run_pool(srv, lambda name, meta: (True, meta["x"] * 2), workers=3,
                   steal_n=4)
    assert len(rep.completed) == 25
    assert rep.results["t7"].value == 14


# ------------------------------------------------------- bounded state


def test_trace_ring_buffer_bounds_memory():
    tr = TraceRecorder(max_events=100)
    for i in range(500):
        tr.emit("x", task=f"t{i}")
    assert len(tr.events) == 100
    assert tr.dropped == 400
    assert tr.n_emitted == 500
    # newest events are the ones retained
    assert tr.events[-1].task == "t499" and tr.events[0].task == "t400"
    unbounded = TraceRecorder()
    unbounded.emit("x")
    assert unbounded.dropped == 0


def test_client_with_ring_buffer_and_no_results_history():
    with Client(workers=2, max_trace_events=64, keep_results=False) as c:
        fs = [c.submit(lambda x=x: x) for x in range(100)]
        assert c.gather(fs) == list(range(100))
        assert len(c.engine.tracer.events) <= 64
        assert c.engine.tracer.dropped > 0
    assert c.close().results == {}        # history opt-out held


def test_server_and_engine_prune_terminal():
    srv = TaskServer()
    boss = DworkClient(InProcTransport(srv), "boss")
    for i in range(20):
        boss.create(f"t{i}", meta={})
    from repro.core.dwork import run_pool
    run_pool(srv, lambda name, meta: True, workers=2)
    assert len(srv.joins) == 20 and srv._all_done()
    assert len(srv.prune_terminal()) == 20
    assert not srv.joins and not srv.meta and not srv.completed
    assert srv._all_done()                 # 0 terminal >= 0 tasks
    # the server still serves fresh work after a prune
    boss.create("fresh", meta={})
    rep = run_pool(srv, lambda name, meta: True, workers=1)
    assert "fresh" in rep.completed


def test_resolved_future_as_dep_survives_pruning():
    # a resolved Future is a satisfied dependency: it must NOT be
    # re-declared server-side (after prune_terminal the name is gone and
    # a re-declare would resurrect it as a READY stub and wedge the
    # dependent)
    with Client(workers=2) as c:
        a = c.submit(lambda: 21)
        assert a.result(10) == 21
        c.drain()
        c.prune()
        b = c.submit(lambda v: v * 2, a)       # value still flows via _peek
        assert b.result(5) == 42
    # a FAILED resolved dep still poisons, even after pruning forgot it
    with Client(workers=2) as c:
        bad = c.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            bad.result(10)
        c.drain()
        c.prune()
        late = c.submit(lambda v: v, bad)
        with pytest.raises(DependencyFailed):
            late.result(5)
        gated = c.submit_task("gated-after-prune", deps=[bad])
        with pytest.raises(DependencyFailed):
            gated.result(5)


def test_name_dep_on_pruned_task_completes_instead_of_wedging():
    # a string-name dep can re-declare a pruned name as a server stub;
    # the engine must report the stub's terminal state (it knows the
    # name already finished) rather than silently dropping the steal —
    # otherwise the dependent waits forever
    done = []
    with Client(workers=1, prune_every=1,
                executor=lambda n, m: done.append(n) or True) as c:
        a = c.submit_task("A")
        assert a.exception(10) is None
        c.drain()
        c.prune()                       # 'A' forgotten on both layers
        b = c.submit_task("B", deps=["A"])
        assert b.exception(10) is None  # resolved, not wedged
        assert done.count("A") == 1     # the stub never re-executed


def test_duplicate_key_rejected_without_orphaning_original():
    with Client(workers=1) as c:
        f1 = c.submit(lambda: 1, key="dup")
        with pytest.raises(ValueError):
            c.submit(lambda: 2, key="dup")
        assert f1.result(10) == 1       # original future still resolves


def test_loop_crash_fails_pending_futures():
    c = Client(workers=1)
    f = c.submit(lambda: 1)

    def boom(tasks):
        raise RuntimeError("backend died")

    c.engine.backend.create_many = boom
    c.start()
    with pytest.raises(TaskFailed, match="loop died"):
        f.result(10)                    # surfaced, not a hang
    with pytest.raises(RuntimeError, match="backend died"):
        c.close()                       # shutdown re-raises the cause


def test_prune_of_poisoned_waiting_task_survives_live_dep_completion():
    # A fails -> poisons dep-waiting B while C (B's other dep) still
    # runs; an aggressive auto-prune drops B from the server tables —
    # C's later Complete must skip the pruned successor, not KeyError
    # the dispatch loop
    import threading as _t
    gate = _t.Event()
    with Client(workers=2, transport="thread", prune_every=1) as c:
        bad = c.submit(lambda: 1 / 0, key="A")
        slow = c.submit(lambda: gate.wait(5), key="C")
        dep = c.submit(lambda a, s: None, bad, slow, key="B")
        with pytest.raises(ZeroDivisionError):
            bad.result(10)
        c.prune()
        gate.set()
        assert slow.result(10) is True
        with pytest.raises(DependencyFailed):
            dep.result(10)
    c.close()                               # loop exited cleanly


def test_submit_after_close_raises():
    c = Client(workers=1)
    f = c.submit(lambda: 1)
    c.close()
    assert f.result(5) == 1
    with pytest.raises(RuntimeError, match="closed"):
        c.submit(lambda: 2)
    with pytest.raises(RuntimeError, match="closed"):
        c.submit_task("late")


def test_client_prune_every_keeps_tables_bounded():
    with Client(workers=2, prune_every=10, keep_results=False) as c:
        fs = [c.submit(lambda x=x: x) for x in range(60)]
        assert c.gather(fs) == list(range(60))
        c.prune()                          # flush the tail
        assert len(c.engine.tasks) < 60
        assert len(c.engine.backend.server.joins) < 60


def _cross_shard_pair(hub):
    """A (producer, dependent) name pair homing on different shards
    (hash-based routing is seed-dependent, so probe for one)."""
    a = "prod0"
    sa = hub._shard_of(a)
    for i in range(64):
        b = f"dep{i}"
        if hub._shard_of(b) != sa:
            return a, b
    raise AssertionError("no cross-shard pair found")


def test_sharded_cancel_poisons_cross_shard_dependent():
    from repro.core.dwork.sharded import ShardedHub

    hub = ShardedHub(2)
    a, b = _cross_shard_pair(hub)
    hub.create(a)
    hub.create(b, deps=[a])
    assert hub.cancel(a) is True
    # the dependent must FAIL, not dangle on its never-released proxy
    sb = hub._shard_of(b)
    assert b in hub.shards[sb].errors
    assert all(s._all_done() for s in hub.shards)


def test_sharded_failure_poisons_cross_shard_dependent():
    from repro.core.dwork.api import Steal, TaskMsg
    from repro.core.dwork.sharded import ShardedHub

    hub = ShardedHub(2)
    a, b = _cross_shard_pair(hub)
    hub.create(a)
    hub.create(b, deps=[a])
    sa = hub._shard_of(a)
    r = hub.shards[sa].handle(Steal(worker=f"w0@{sa}", n=1))
    assert isinstance(r, TaskMsg) and r.tasks[0][0] == a
    hub.complete("w0", a, sa, ok=False)
    sb = hub._shard_of(b)
    assert b in hub.shards[sb].errors
    assert all(s._all_done() for s in hub.shards)


# ------------------------------------------------- idempotent lifecycle


def test_shutdown_of_never_started_resident_engine_is_noop():
    eng = Engine(workers=1, resident=True)
    assert eng.shutdown() is None          # never started: safe no-op
    assert eng.shutdown() is None


def test_double_shutdown_returns_first_report():
    eng = Engine(workers=1, resident=True)
    eng.start()
    eng.submit("a", fn=lambda: 1)
    rep = eng.shutdown()
    assert "a" in rep.completed
    assert eng.shutdown() is rep           # idempotent, same report
    # and the batch-mode guard is still strict
    with pytest.raises(RuntimeError):
        Engine(workers=1).shutdown()


def test_batch_submit_after_run_rejected():
    c = Client(resident=False, workers=1)
    f = c.submit(lambda: 1)
    c.run()
    assert f.result() == 1
    with pytest.raises(RuntimeError, match="one-shot"):
        c.submit(lambda: 2)
    c.close()


def test_submit_after_loop_death_rejected():
    c = Client(workers=1)
    f = c.submit(lambda: 1)
    c.engine.backend.create_many = lambda tasks: (_ for _ in ()).throw(
        RuntimeError("backend died"))
    c.start()
    with pytest.raises(TaskFailed, match="loop died"):
        f.result(10)
    with pytest.raises(RuntimeError, match="dispatch loop died"):
        c.submit(lambda: 2)         # dead loop: refuse new work


def test_cancel_of_lease_requeued_task_refused():
    # a lease-expired requeue may still be EXECUTING on its straggler
    # worker: "cancelled" must mean "never runs", so refuse
    from repro.core.dwork.api import Cancel, NotFound, Steal
    from repro.core.engine import ManualClock

    clock = ManualClock()
    srv = TaskServer(lease_timeout=1.0, clock=clock)
    boss = DworkClient(InProcTransport(srv), "boss")
    boss.create("t", meta={})
    srv.handle(Steal(worker="w0", n=1))      # stolen, lease starts
    clock.advance(5.0)
    srv.handle(Steal(worker="w1", n=0))      # reap: t requeued
    assert "t" in srv.requeued_tasks
    assert isinstance(srv.handle(Cancel(task="t")), NotFound)


def test_client_close_is_idempotent_and_enter_after_close_rejected():
    c = Client(workers=1)
    with c:
        f = c.submit(lambda: 5)
        assert f.result(10) == 5
    rep = c.close()                        # second close: no-op
    assert rep is c.close()
    with pytest.raises(RuntimeError):
        c.start()


def test_lazy_client_close_runs_pending_work():
    # the inline-transport client starts its loop lazily: a close(drain=
    # True) with pending futures starts + drains so nothing is lost
    c = Client(workers=1)
    f = c.submit(lambda: 1)
    rep = c.close()
    assert f.result(1) == 1 and rep is not None
    # drain=False abandons instead: the future fails loudly, never hangs
    c2 = Client(workers=1)
    f2 = c2.submit(lambda: 1)
    assert c2.close(drain=False) is None
    with pytest.raises(TaskFailed):
        f2.result(1)


# ----------------------------------------------------- serving + elastic


def test_client_serve_roundtrip_and_close():
    with Client(workers=2, lease_timeout=30.0) as c:
        fe = c.serve(lambda payloads: [p * 2 for p in payloads],
                     max_wait_s=0.002)
        reqs = [fe.submit(i) for i in range(20)]
        for i, r in enumerate(reqs):
            assert r.wait(30.0) and r.ok
            assert r.value == i * 2
    rep = c.close()
    lat = rep.trace.latency_report()
    assert lat.n_requests == 20 and lat.n_failed == 0


def test_elastic_pool_futures():
    from repro.runtime.elastic import ElasticPool

    with ElasticPool(lease_timeout=5.0) as pool:
        pool.start_worker("a", lambda name, meta: True)
        fs = [pool.submit(f"s{i}") for i in range(10)]
        pool.join(30.0)
        # executor-style tasks return ok=True with no value: success is
        # "resolved without exception"
        assert all(isinstance(f, Future) and f.exception(5) is None
                   for f in fs)
        assert len(pool.completed) == 10


def test_executor_worker_crash_requeues_not_fails():
    crashed = []

    def execute(name, meta, worker):
        if not crashed:
            crashed.append(worker)
            raise WorkerCrash("drill")
        return True

    with Client(workers=2, executor=execute, pass_worker=True) as c:
        fs = [c.submit_task(f"n{i}") for i in range(10)]
        assert c.gather(fs) == [None] * 10     # ok=True, no value
        assert all(f.exception() is None for f in fs)
        assert c.report().n_requeued >= 1
