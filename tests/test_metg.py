"""METG scaling-law validation against the paper's own measurements
(Table 4, §4): pmake ~ log(P) + alloc; dwork ~ rtt*P; orderings at 864."""
import math

from repro.core.metg import (PAPER_JSRUN, PAPER_METG_864, METGModel,
                             efficiency, pick_batch_size)


def test_jsrun_log_fit_matches_table4():
    m = METGModel.from_paper()
    for ranks, t in PAPER_JSRUN.items():
        assert abs(m.jsrun_time(ranks) - t) < 0.5, (ranks, m.jsrun_time(ranks))


def test_paper_metg_ordering_at_864():
    """Paper §4: 'the METG for mpi-list, dwork and pmake are 0.3, 25, and
    4500 milliseconds' — reproduce the ordering and magnitudes."""
    m = METGModel.from_paper()
    mpil = m.mpilist_metg(864, per_rank_sigma=0.3e-3 / math.sqrt(2 * math.log(864)))
    dw = m.dwork_metg(864)
    pm = m.pmake_metg(864)
    assert mpil < dw < pm
    assert 0.1e-3 < mpil < 1e-3                    # ~0.3 ms
    assert 10e-3 < dw < 40e-3                      # ~20-25 ms
    assert 3.5 < pm < 5.5                          # ~4.5 s


def test_dwork_linear_scaling():
    m = METGModel.from_paper()
    assert abs(m.dwork_metg(2 * 864) / m.dwork_metg(864) - 2.0) < 1e-9
    # paper §5: 23 us => only ~44k tasks/s; 44k ranks need >= 1 s tasks
    assert 0.9 < m.dwork_metg(44000) < 1.1


def test_dwork_mitigations():
    m = METGModel.from_paper()
    assert m.dwork_metg(864, steal_n=8) < m.dwork_metg(864) / 7.9
    assert m.dwork_metg(864, shards=4) < m.dwork_metg(864) / 3.9


def test_pmake_log_scaling():
    m = METGModel.from_paper()
    d1 = m.pmake_metg(60) - m.pmake_metg(6)
    d2 = m.pmake_metg(600) - m.pmake_metg(60)
    assert abs(d1 - d2) < 0.2                      # log-law: equal decade steps


def test_mpilist_gumbel_growth():
    m = METGModel.from_paper()
    g = [m.mpilist_metg(p, per_rank_sigma=1e-3) for p in (8, 64, 4096)]
    assert g[0] < g[1] < g[2]
    # sqrt(2 ln P) growth: P grew 512x but the gap only ~2x
    assert g[2] < 2.1 * g[0]


def test_efficiency_definition():
    """At task == METG, half the time is overhead (the METG definition)."""
    assert abs(efficiency(1.0, 1.0) - 0.5) < 1e-12
    assert efficiency(10.0, 1.0) > 0.9


def test_pick_batch_size():
    n = pick_batch_size("dwork", ranks=864, per_task_s=0.001, target_eff=0.9)
    m = METGModel.from_paper()
    eff = 0.001 * n / (0.001 * n + m.dwork_metg(864))
    assert eff >= 0.9
    assert pick_batch_size("dwork", 6, per_task_s=1.0) == 1
