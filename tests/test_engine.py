"""Unified execution engine tests: lifecycle tracing, worker-pool
transports, fault injection (dead workers, poisoned tasks, heartbeat
leases, seeded stragglers), sharded routing, and the empirical-vs-analytic
METG crosscheck for all three schedulers (the paper's §3-§6 claims,
measured on the running code)."""
import pytest

from repro.core.dwork import Client, InProcTransport, TaskServer, run_pool
from repro.core.engine import (COMPLETED, CREATED, READY, RUN_END, RUN_START,
                               STOLEN, Engine, FaultPlan, ManualClock,
                               TraceRecorder, crosscheck)
from repro.core.metg import METGModel, PAPER_DWORK_RTT
from repro.core.mpi_list import Context
from repro.core.pmake import PMake


def flat_engine(n, workers=4, **kw):
    eng = Engine(workers=workers, transport="inproc", **kw)
    for i in range(n):
        eng.submit(f"t{i}", fn=lambda: None)
    return eng


def diamond_engine(n=1000, workers=4, **kw):
    """1 root -> (n-2) parallel mids -> 1 sink (the 1k diamond DAG)."""
    eng = Engine(workers=workers, transport="inproc", **kw)
    mids = [f"mid{i}" for i in range(n - 2)]
    eng.submit("root", fn=lambda: None)
    for m in mids:
        eng.submit(m, fn=lambda: None, deps=["root"])
    eng.submit("sink", fn=lambda: None, deps=mids)
    return eng, mids


# ---------------------------------------------------------------- basics


def test_dag_execution_values_and_order():
    eng = Engine(workers=2, transport="inproc")
    eng.submit("a", fn=lambda: 1)
    eng.submit("b", fn=lambda: 2, deps=["a"])
    eng.submit("c", fn=lambda: 3, deps=["a", "b"])
    rep = eng.run()
    assert rep.completed == {"a", "b", "c"} and not rep.stalled
    assert rep.results["c"].value == 3
    runs = [e.task for e in rep.trace.of(RUN_START)]
    assert runs.index("a") < runs.index("b") < runs.index("c")


def test_lifecycle_event_order_deterministic_clock():
    clk = ManualClock(tick=1e-6)
    eng = Engine(workers=1, transport="inproc", clock=clk)
    eng.submit("x", fn=lambda: "v")
    eng.submit("y", fn=lambda: "w", deps=["x"])
    rep = eng.run()
    for task in ("x", "y"):
        ts = {ev: next(e.t for e in rep.trace.of(ev) if e.task == task)
              for ev in (CREATED, READY, STOLEN, RUN_START, RUN_END,
                         COMPLETED)}
        assert (ts[CREATED] <= ts[READY] <= ts[STOLEN] <= ts[RUN_START]
                <= ts[RUN_END] <= ts[COMPLETED]), (task, ts)


def test_priority_order_within_one_steal_batch_fast_path():
    """Regression: the fault-free inline fast path must not drain a
    prio-0 task before a higher-priority one later in the SAME batch."""
    order = []
    eng = Engine(workers=1, transport="inproc", steal_n=4)
    eng.submit("low", fn=lambda: order.append("low"), priority=0.0)
    eng.submit("high", fn=lambda: order.append("high"), priority=9.0)
    rep = eng.run()
    assert order == ["high", "low"] and rep.completed == {"high", "low"}


def test_priority_and_slots_pmake_semantics():
    """The launch step is pmake's greedy highest-priority-first; a task
    wanting more slots than the allocation is clamped, not starved."""
    order = []
    eng = Engine(workers=2, transport="inproc", steal_n=8)
    eng.submit("low", fn=lambda: order.append("low"), priority=1.0)
    eng.submit("hi", fn=lambda: order.append("hi"), priority=10.0, slots=16)
    rep = eng.run()
    assert order == ["hi", "low"] and rep.completed == {"hi", "low"}


def test_steal_n_batching_reduces_rpcs():
    n1 = flat_engine(200, steal_n=1).run().overhead().n_rpc
    n8 = flat_engine(200, steal_n=8).run().overhead().n_rpc
    assert n8 < n1


def test_sharded_routing():
    eng = Engine(workers=4, shards=2, steal_n=4, transport="inproc")
    for i in range(200):
        eng.submit(f"s{i}", deps=[f"s{i - 20}"] if i >= 20 else ())
    rep = eng.run()
    assert len(rep.completed) == 200 and not rep.stalled
    assert len(rep.backend_stats["shards"]) == 2
    # both shards actually served tasks (hash routing + work stealing)
    assert all(s["completed"] > 0 for s in rep.backend_stats["shards"])


# ---------------------------------------------- the CompleteSteal batch verb


def test_complete_steal_one_round_trip_both_directions():
    """CompleteSteal applies the finished batch FIRST, then serves the
    steal — so completing a producer and stealing its freed successor
    works in a single round-trip."""
    from repro.core.dwork.api import ExitResp, TaskMsg
    srv = TaskServer()
    cl = Client(InProcTransport(srv), "w0")
    cl.create("a")
    cl.create("b", deps=["a"])
    cl.create("c")
    got = cl.steal(n=2)
    assert [t for t, _m in got.tasks] == ["a", "c"]
    r = cl.complete_steal([("a", True), ("c", True)], n=2)
    assert isinstance(r, TaskMsg)
    assert [t for t, _m in r.tasks] == ["b"]       # freed by the batch
    assert srv.counters["completed"] == 2
    # complete-only (n=0) returns ExitResp and never steals
    assert isinstance(cl.complete_steal([("b", True)], n=0), ExitResp)
    assert srv.counters["completed"] == 3
    assert isinstance(cl.steal(), ExitResp)        # everything terminal


def test_complete_steal_failed_batch_entry_poisons():
    from repro.core.dwork.api import ExitResp
    srv = TaskServer()
    cl = Client(InProcTransport(srv), "w0")
    cl.create("a")
    cl.create("kid", deps=["a"])
    cl.steal()
    assert isinstance(cl.complete_steal([("a", False)], n=1), ExitResp)
    assert srv.errors == {"a", "kid"}


def test_complete_clears_duplicate_assignment_after_requeue():
    """A late Complete for a task that was lease-requeued and re-stolen
    must clear the re-stealer's assignment too (exactly-once terminal:
    no stale server-side state for any holder)."""
    srv = TaskServer(lease_timeout=0.0)    # immediate expiry
    slow = Client(InProcTransport(srv), "slow")
    slow.create("a")
    assert slow.steal().tasks[0][0] == "a"
    fast = Client(InProcTransport(srv), "fast")
    assert fast.steal().tasks[0][0] == "a"         # re-stolen after expiry
    slow.complete("a")                             # late straggler report
    assert srv.assigned.get("fast", set()) == set()
    assert srv.assigned.get("slow", set()) == set()
    assert srv.counters["completed"] == 1


def test_complete_steal_wire_round_trip():
    from repro.core.dwork.api import CompleteSteal, decode, encode
    msg = CompleteSteal(worker="w0", done=[("a", True), ("b", False)], n=3)
    back = decode(encode(msg))
    assert isinstance(back, CompleteSteal)
    assert back.worker == "w0" and back.n == 3
    assert [(t, bool(ok)) for t, ok in back.done] == \
        [("a", True), ("b", False)]


def test_engine_batches_rpcs_via_complete_steal():
    """The engine's dispatch loop must piggyback completions on steals:
    at steal_n=8 a 200-task flat run needs far fewer round-trips than
    one per task (plus the 200 creates)."""
    rep = flat_engine(200, steal_n=8).run()
    ov = rep.overhead()
    ops = {op for op in ov.rpc_by_op}
    assert "complete_steal" in ops
    assert "complete" not in ops           # no unbatched completes
    assert ov.n_rpc < 200 + 200 // 4       # creates + amortized dispatch


# --------------------------------------------------------- fault injection


def test_dead_worker_mid_1k_diamond_zero_lost_tasks():
    """Kill a worker mid-run of a 1k-task diamond DAG: its stolen-but-
    unfinished tasks are recycled (Exit -> FRONT of queue), no task is
    lost, and every successor eventually completes.  Deterministic: the
    inproc transport round-robins with no wall-clock dependence."""
    faults = FaultPlan(seed=7).kill_worker("w2", after_steals=100)
    eng, mids = diamond_engine(1000, workers=4, steal_n=8, faults=faults)
    rep = eng.run()
    assert not rep.stalled
    assert len(rep.completed) == 1000            # zero lost tasks
    assert rep.completed >= set(mids) | {"root", "sink"}
    ov = rep.overhead()
    assert ov.n_requeued >= 1                    # the dead worker's batch
    dead = [e for e in rep.trace.events if e.event == "worker_dead"]
    assert [e.worker for e in dead] == ["w2"]
    # w2 never completes anything after death: its results were discarded
    assert rep.backend_stats["completed"] == 1000


def test_failed_task_poisons_transitive_successors_in_diamond():
    faults = FaultPlan(seed=7).fail_task("mid500")
    eng, mids = diamond_engine(1000, workers=4, steal_n=8, faults=faults)
    rep = eng.run()
    assert not rep.stalled
    assert rep.errors == {"mid500", "sink"}      # transitive poisoning
    assert len(rep.completed) == 998             # everything else completed
    # zero lost: every task reached a terminal state
    assert len(rep.completed) + len(rep.errors) == 1000


def test_silent_death_recovered_by_heartbeat_lease():
    """A silently-dead worker sends no Exit; the heartbeat lease (manual
    clock — deterministic) expires and its tasks are re-queued."""
    clk = ManualClock(tick=1e-3)
    faults = FaultPlan(seed=3).kill_worker("w1", after_steals=2, silent=True)
    eng = Engine(workers=2, transport="inproc", steal_n=2, clock=clk,
                 lease_timeout=0.05, faults=faults)
    for i in range(20):
        eng.submit(f"x{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 20 and not rep.stalled
    assert rep.overhead().n_requeued >= 1


def test_lease_requeue_exactly_once_engine_server():
    """Engine-level mirror of the dwork lease regression (runs without
    hypothesis): expired lease requeues to the FRONT exactly once; a late
    Complete never causes a double-execution."""
    clock = {"now": 0.0}
    srv = TaskServer(lease_timeout=1.0, clock=lambda: clock["now"])
    slow = Client(InProcTransport(srv), "slow")
    slow.create("a")
    slow.create("b")
    assert slow.steal().tasks[0][0] == "a"
    clock["now"] = 2.0
    srv._reap_leases()
    assert srv.counters["requeued"] == 1
    assert list(srv.ready)[0] == "a"             # FRONT of the deque
    slow.complete("a")                           # late straggler Complete
    assert srv.counters["requeued"] == 1         # no double-requeue
    rep = run_pool(srv, lambda n, m: True, workers=2)
    assert rep.backend_stats["completed"] == 2   # "a" exactly once
    assert srv.counters["completed"] == 2
    assert "a" not in rep.results                # stale entry never served
    assert srv.counters["stolen"] == 2           # a once (slow), b once


def test_run_pool_inherits_server_lease_for_idle_budget():
    """run_pool must size the engine's idle budget from the server's
    heartbeat lease: a silently-dead worker's tasks are reaped after
    lease expiry instead of being abandoned as a premature stall."""
    clk = ManualClock(tick=1e-3)
    srv = TaskServer(lease_timeout=1.0, clock=clk)
    boss = Client(InProcTransport(srv), "boss")
    for i in range(6):
        boss.create(f"t{i}")
    rep = run_pool(srv, lambda n, m: True, workers=2, steal_n=2, clock=clk,
                   faults=FaultPlan(seed=1).kill_worker(
                       "w0", after_steals=1, silent=True))
    assert len(rep.completed) == 6 and not rep.stalled
    assert rep.overhead().n_requeued >= 1


def test_straggler_injection_deterministic_with_seed():
    def run_ctx(seed):
        C = Context(16, engine_workers=4, straggler_sigma=1e-3, seed=seed)
        C.scatter(list(range(64))).map(lambda x: x + 1)
        return C.virtual_gaps[0]

    assert run_ctx(42) == run_ctx(42)
    assert run_ctx(42) != run_ctx(43)


def test_dead_worker_with_inflight_task_thread_transport():
    """Announced death while a task is mid-flight on the thread pool: the
    requeued task is re-stolen by a live worker and must eventually run
    (the dead copy's completion is discarded, so the re-steal is its only
    way forward)."""
    import time as _t
    faults = FaultPlan(seed=5).kill_worker("w1", after_steals=3)
    eng = Engine(workers=2, transport="thread", steal_n=2, faults=faults,
                 poll=0.002)
    for i in range(12):
        eng.submit(f"t{i}", fn=lambda: _t.sleep(0.05))
    rep = eng.run()
    assert len(rep.completed) == 12 and not rep.stalled
    assert rep.backend_stats["assigned"] == 0    # nothing stuck leased


def test_lease_shorter_than_task_keeps_server_state_clean():
    """A task longer than the heartbeat lease is re-stolen while its live
    copy runs; the suppressed duplicate must not leave a stale entry in
    the server's assigned map once the task completes."""
    import time as _t
    eng = Engine(workers=2, transport="thread", lease_timeout=0.05,
                 poll=0.002, steal_n=1)
    eng.submit("slowpoke", fn=lambda: _t.sleep(0.2))
    for i in range(4):
        eng.submit(f"quick{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 5 and not rep.stalled
    assert rep.backend_stats["assigned"] == 0    # no stale leases


def test_pmake_global_priority_on_node_limited_allocation():
    """With one node, the high-EFT rule's tasks must all launch before any
    low-priority ones — global greedy priority, not per-batch."""
    import tempfile
    rules = """
big:
  resources: {time: 600, nrs: 1}
  out: {o: "big_{n}.out"}
  script: "echo {n}"
small:
  resources: {time: 1, nrs: 1}
  out: {o: "small_{n}.out"}
  script: "echo {n}"
"""
    targets = ('t:\n  dirname: .\n  loop: {n: "range(6)"}\n'
               '  tgt: {b: "big_{n}.out", s: "small_{n}.out"}\n')
    ran = []
    pm = PMake(rules, targets, root=tempfile.mkdtemp(), total_nodes=1,
               transport="inproc", runner=lambda t: ran.append(t.rule.name)
               or True)
    stats = pm.run()
    assert stats["done"] == 12 and stats["errors"] == 0
    assert ran[:6] == ["big"] * 6                # EFT order, all batches


def test_straggler_crosscheck_requires_injected_sigma():
    C = Context(8, engine_workers=2)             # engine mode, no injection
    C.scatter(list(range(16))).map(lambda x: x)
    with pytest.raises(ValueError):
        C.straggler_crosscheck()


def test_pmake_chain_respects_dependency_order():
    """Regression: tasks must be submitted producers-first; a dependent
    submitted before its producer would be forward-declared READY and run
    against missing inputs (3-level chain with a slow upstream)."""
    import tempfile
    import time as _t
    rules = """
stage_a:
  resources: {time: 1, nrs: 1}
  out: {o: "a.txt"}
  script: "echo a > a.txt"
stage_b:
  resources: {time: 1, nrs: 1}
  inp: {i: "a.txt"}
  out: {o: "b.txt"}
  script: "cp a.txt b.txt"
stage_c:
  resources: {time: 1, nrs: 1}
  inp: {i: "b.txt"}
  out: {o: "c.txt"}
  script: "cp b.txt c.txt"
"""
    targets = 't:\n  dirname: .\n  tgt: {o: "c.txt"}\n'
    ran = []

    def runner(task):
        ran.append(task.rule.name)
        _t.sleep(0.05 if task.rule.name == "stage_a" else 0.0)
        return True

    pm = PMake(rules, targets, root=tempfile.mkdtemp(), total_nodes=4,
               runner=runner)
    stats = pm.run()
    assert stats["done"] == 3 and stats["errors"] == 0
    assert ran == ["stage_a", "stage_b", "stage_c"]


def test_overhead_report_pairs_reexecutions_sequentially():
    """A requeued task emits two run_start/run_end pairs; compute time
    must pair them per execution, never across (no negative durations)."""
    from repro.core.engine import RUN_END, RUN_START, STOLEN as ST
    tr = TraceRecorder(clock=lambda: 0.0)

    def ev(event, t, task, **extra):
        e = tr.emit(event, task=task, **extra)
        e.t = t

    ev(ST, 0.0, "x")
    ev(RUN_START, 1.0, "x")
    ev(RUN_END, 2.0, "x")              # first execution: 1s
    ev(ST, 4.0, "x")                   # requeued + re-stolen
    ev(RUN_START, 5.0, "x")
    ev(RUN_END, 7.0, "x")              # second execution: 2s
    ev(COMPLETED, 7.0, "x")
    rep = tr.report(workers=1)
    assert rep.compute_s == pytest.approx(3.0)
    assert rep.dispatch_s == pytest.approx(2.0)   # 1s + 1s stolen->start


def test_all_workers_dead_with_remaining_work_reports_stall():
    """Every worker dying mid-run must NOT look like a clean finish:
    the abandoned tasks are a stall the caller can detect."""
    faults = (FaultPlan(seed=1).kill_worker("w0", after_steals=1)
              .kill_worker("w1", after_steals=1))
    eng = Engine(workers=2, steal_n=2, faults=faults, max_idle_rounds=30)
    for i in range(50):
        eng.submit(f"t{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) < 50
    assert rep.stalled                           # not a clean exit


def test_thread_overhead_accounting_capped_by_capacity():
    """ThreadPoolExecutor is sized by `capacity`; phantom workers above
    it must not be billed as idle scheduler overhead."""
    import time as _t
    eng = Engine(workers=8, capacity=2, transport="thread", steal_n=1,
                 poll=0.002)
    for i in range(8):
        eng.submit(f"t{i}", fn=lambda: _t.sleep(0.03))
    rep = eng.run()
    assert len(rep.completed) == 8
    assert rep.workers == 2                      # min(workers, capacity)
    # 8 x 30ms over 2 real slots: overhead must stay far below the
    # ~90ms/task that billing 6 phantom workers would produce
    assert rep.overhead().per_task_overhead_s < 0.03


# -------------------------------------- the 1,000-task METG acceptance run


def work(x):
    return x * x


@pytest.fixture(scope="module")
def thousand_task_results():
    """One identical 1,000-task workload (square 1000 ints) through all
    three schedulers via the engine, with traces.

    GC is paused during the measured runs: with the full suite's heap
    (jax caches etc.) resident, gen-2 collections otherwise land inside
    the trace spans and swamp the ~30 us/task scheduler overhead."""
    import gc
    gc.collect()
    gc.disable()
    out = {}

    # dwork: 1000 independent tasks on a TaskServer, engine pool
    srv = TaskServer()
    boss = Client(InProcTransport(srv), "boss")
    for i in range(1000):
        boss.create(f"sq{i}", meta={"x": i})
    rep = run_pool(srv, lambda name, meta: (True, work(meta["x"])),
                   workers=4, steal_n=1)
    out["dwork"] = rep

    # pmake: 1000-target ruleset, engine pool with runner override
    rules = ('sq:\n  resources: {time: 1, nrs: 1}\n'
             '  out: {o: "sq_{n}.out"}\n  script: "echo {n}"\n')
    targets = ('all:\n  dirname: .\n  loop:\n    n: "range(1000)"\n'
               '  tgt: {o: "sq_{n}.out"}\n')
    import tempfile
    pm = PMake(rules, targets, root=tempfile.mkdtemp(), total_nodes=4,
               transport="inproc", runner=lambda t: True)
    out["pmake_stats"] = pm.run()
    out["pmake"] = pm.report

    # mpi-list: the same 1000 elements, 16 ranks, engine-backed supersteps
    C = Context(16, engine_workers=4, straggler_sigma=1e-3, seed=0)
    dfm = C.scatter(list(range(1000))).map(work)
    out["mpilist_collect"] = dfm.collect()
    out["mpilist_ctx"] = C
    gc.enable()
    return out


def test_identical_workload_completes_on_all_three(thousand_task_results):
    r = thousand_task_results
    assert len(r["dwork"].completed) == 1000 and not r["dwork"].stalled
    assert all(r["dwork"].results[f"sq{i}"].value == i * i
               for i in range(0, 1000, 97))
    assert r["pmake_stats"]["done"] == 1000
    assert r["pmake_stats"]["errors"] == 0
    assert r["mpilist_collect"] == [work(i) for i in range(1000)]


def test_empirical_overhead_crosschecks_analytic_metg(thousand_task_results):
    """tracing.py reports empirical per-task overhead for each scheduler,
    same order of magnitude as the core/metg.py analytic laws evaluated
    with constants measured from the same traces."""
    r = thousand_task_results

    # dwork: METG(P) = rtt * P / steal_n, rtt measured at the server
    ov = r["dwork"].overhead()
    assert ov.n_tasks == 1000 and ov.per_task_overhead_s > 0
    model = METGModel.from_measured(rtt_s=ov.rpc_per_task_s)
    chk = crosscheck("dwork", ov.per_task_overhead_s,
                     model.dwork_metg(r["dwork"].workers * 4, steal_n=1))
    assert chk["same_order"], chk
    # and our in-proc RTT analog is within ~30x of the paper's 23 us
    assert crosscheck("dwork-rtt", ov.rpc_per_task_s, PAPER_DWORK_RTT,
                      factor=30.0)["same_order"]

    # pmake: METG = launch + alloc; our "launch" constant is the measured
    # per-task scheduler round-trip cost, cross-checked against the
    # independent span-based overhead (wall minus compute, per task)
    pv = r["pmake"].overhead()
    assert pv.n_tasks == 1000 and pv.per_task_overhead_s > 0
    pmodel = METGModel.from_measured(launch_s=pv.rpc_per_task_s)
    chk = crosscheck("pmake", pv.per_task_overhead_s, pmodel.pmake_metg(4))
    assert chk["same_order"], chk

    # mpi-list: sync gap vs Gumbel sigma*sqrt(2 ln P) at the injected sigma
    chk = thousand_task_results["mpilist_ctx"].straggler_crosscheck()
    assert chk["same_order"], chk


def test_trace_counts_conserved(thousand_task_results):
    """Every created task is stolen and reaches exactly one terminal event
    (requeues may add extra steals, never extra completions)."""
    tr = thousand_task_results["dwork"].trace
    assert tr.count(COMPLETED) == 1000
    assert tr.count(STOLEN) >= 1000
    done_tasks = {e.task for e in tr.of(COMPLETED)}
    assert len(done_tasks) == 1000
