"""HLO-analysis unit tests: the roofline's flop/byte/collective accounting
(incl. the while-trip-count correction that XLA's cost_analysis lacks)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_module, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2,2]{1,0}, s32[3]{0})") == 28
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("f32[]") == 4


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplier():
    """A scan of 8 matmuls must count 8x one matmul (cost_analysis counts 1)."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def one(x, w):
        return x @ w[0]

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    f1 = analyze(_compile(one, x, w))["flops"]
    f8 = analyze(_compile(scanned, x, w))["flops"]
    assert f1 > 0
    assert abs(f8 / f1 - 8.0) < 0.2, (f1, f8)


def test_dot_flops_value():
    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    y = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    fl = analyze(_compile(lambda a, b: a @ b, x, y))["flops"]
    assert abs(fl - 2 * 128 * 64 * 32) / (2 * 128 * 64 * 32) < 0.05


def test_dus_counts_slice_not_buffer():
    """Scan residual-stacking must count slice traffic, not L x buffer."""
    x = jax.ShapeDtypeStruct((64, 1024), jnp.float32)

    def stack(x):
        def body(c, _):
            c = c * 1.0001
            return c, c                    # ys stacking => DUS per step
        _, ys = jax.lax.scan(body, x, None, length=32)
        return ys

    hbm = analyze(_compile(stack, x))["hbm_bytes"]
    buf = 32 * 64 * 1024 * 4
    # must be O(total stacked bytes), not O(L * stacked bytes)
    assert hbm < 12 * buf, (hbm, buf)


def test_parse_module_finds_entry():
    hlo = _compile(lambda a: a + 1.0, jax.ShapeDtypeStruct((4,), jnp.float32))
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps


def test_collectives_counted_with_mesh():
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    if jax.device_count() < 2:
        # single real device: psum lowers away; just assert no crash
        hlo = _compile(lambda a: a * 2,
                       jax.ShapeDtypeStruct((8, 8), jnp.float32))
        assert analyze(hlo)["collectives"]["total"] == 0
        return
