"""Peer-to-peer data plane tests: location tracking on the hub, the
worker-side Ref resolution chain (cache -> store -> hub -> peer ->
recompute), the LRU spill-to-hub policy, zero-loss recompute across a
producer SIGKILL, engine/client RemoteValue materialization, and the
prune regression (terminal pruning must evict the data-plane stores).

The fallback-chain unit tests drive `_DataPlane.resolve` directly with
a scripted stub hub, so every leg of the chain is covered without
process churn; the integration tests then exercise the same legs end to
end over real worker processes.  Task callables are lambdas throughout
(cloudpickle ships them by value across the process boundary)."""
import hashlib
import os
import signal
import time

import pytest

from repro.client import Client
from repro.core.dwork.api import (XFER_LOST_PREFIX, Fetch, LocMsg, NotFound,
                                  ValueMsg)
from repro.core.engine import Engine
from repro.core.engine.comm import core as comm_core
from repro.core.engine.comm.serialize import Ref, RemoteValue, dumps, loads
from repro.core.engine.comm.worker import _DataPlane, _DataServer, _LostDep
from repro.core.engine.model import XFER

HB = 0.1
BIG = 300_000                 # well above every inline_bytes used here


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _wait(pred, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {what}")


# --------------------------------------------- resolve chain (unit, stubs)


class _StubHub:
    """Scripted control-plane transport: each Fetch pops the next canned
    response (or raises it)."""

    def __init__(self, *script):
        self.script = list(script)
        self.calls = []

    def request(self, msg):
        self.calls.append(msg)
        r = self.script.pop(0)
        if isinstance(r, Exception):
            raise r
        return r


@pytest.fixture
def plane_factory():
    planes = []

    def make(*script):
        p = _DataPlane(_StubHub(*script))
        planes.append(p)
        return p

    yield make
    for p in planes:
        p.close()


def test_resolve_non_ref_and_local_caches(plane_factory):
    plane = plane_factory()
    xfers: list = []
    assert plane.resolve(41, xfers) == 41          # not a Ref: pass-through
    plane.cache_obj("a", {"k": 7})
    assert plane.resolve(Ref("a"), xfers) == {"k": 7}
    plane.put("b", dumps([1, 2, 3]), owned=False)
    assert plane.resolve(Ref("b"), xfers) == [1, 2, 3]
    assert plane.transport.calls == []             # never touched the wire
    assert xfers == []                             # local hits: no stats


def test_resolve_hub_value(plane_factory):
    plane = plane_factory(ValueMsg(task="c", payload=dumps("hub-served")))
    xfers: list = []
    assert plane.resolve(Ref("c"), xfers) == "hub-served"
    assert [x[0] for x in xfers] == ["hub"]
    assert plane.resolve(Ref("c"), []) == "hub-served"   # cached now
    assert len(plane.transport.calls) == 1


def test_resolve_peer_redirect_hits_producer(plane_factory):
    class _Peer:
        def handle(self, msg):
            assert isinstance(msg, Fetch)
            return ValueMsg(task=msg.task, payload=dumps(b"x" * 99))

    lst = comm_core.listen("inproc://dp-peer-hit", _Peer())
    try:
        plane = plane_factory(LocMsg(task="d", addr=lst.address,
                                     worker="w9", nbytes=99))
        xfers: list = []
        assert plane.resolve(Ref("d"), xfers) == b"x" * 99
        assert [x[0] for x in xfers] == ["peer"]
        assert xfers[0][1] > 0 and xfers[0][2] >= 0.0
    finally:
        lst.stop()


def test_resolve_dead_peer_falls_back_to_hub(plane_factory):
    # the redirect points at a dead producer; the hub answers the retry
    # (a Spill landed meanwhile) — the chain must recover transparently
    plane = plane_factory(
        LocMsg(task="e", addr="tcp://127.0.0.1:1", worker="w0", nbytes=5),
        ValueMsg(task="e", payload=dumps("spilled")))
    xfers: list = []
    assert plane.resolve(Ref("e"), xfers) == "spilled"
    assert [x[0] for x in xfers] == ["hub"]
    assert len(plane.transport.calls) == 2         # Fetch + hub retry


def test_resolve_unrecoverable_raises_lost_dep(plane_factory):
    # producer dead AND the hub never got a replica: recompute territory
    plane = plane_factory(
        LocMsg(task="f", addr="tcp://127.0.0.1:1", worker="w0", nbytes=5),
        NotFound())
    with pytest.raises(_LostDep) as ei:
        plane.resolve(Ref("f"), [])
    assert ei.value.name == "f"
    assert XFER_LOST_PREFIX + "f" == "__xfer_lost__:f"


def test_resolve_never_known_raises_keyerror(plane_factory):
    plane = plane_factory(NotFound())
    with pytest.raises(KeyError, match="unavailable on the hub"):
        plane.resolve(Ref("g"), [])


def test_data_plane_lru_spill_budget(plane_factory):
    from repro.core.dwork.api import Spill

    class _SpillHub(_StubHub):
        def __init__(self):
            super().__init__()
            self.spilled = []

        def request(self, msg):
            assert isinstance(msg, Spill)
            self.spilled.append(msg.task)
            return NotFound()

    plane = _DataPlane(_SpillHub())
    try:
        plane.me = "wX"
        plane.spill_bytes = 300
        p = dumps(b"y" * 200)                      # ~200B payloads
        plane.put("old", p, owned=True, value=b"y" * 200, have_value=True)
        plane.put("new", p, owned=True, value=b"y" * 200, have_value=True)
        # budget 300 < 2 payloads: the oldest owned value was evicted and
        # replicated to the hub first; the store never drops to empty
        assert plane.transport.spilled == ["old"]
        assert "old" not in plane.store and "old" not in plane.objs
        assert "new" in plane.store
        # borrowed (not owned) evictions never spill — peer copies are
        # cache, the producer still holds the original
        plane.put("borrowed", p, owned=False)
        assert plane.transport.spilled == ["old", "new"]
    finally:
        plane.close()


def test_data_server_serves_store_and_not_found(plane_factory):
    plane = plane_factory()
    plane.put("have", dumps(3), owned=False)
    srv = _DataServer(plane)
    resp = srv.handle(Fetch(task="have"))
    assert isinstance(resp, ValueMsg) and loads(resp.payload) == 3
    assert isinstance(srv.handle(Fetch(task="missing")), NotFound)


def test_remote_value_fetches_once_and_caches():
    calls = []

    def fetch(name):
        calls.append(name)
        return [name, 1]

    rv = RemoteValue("t9", 1234, fetch)
    assert not rv.resolved and rv.nbytes == 1234
    assert rv.get() == ["t9", 1]
    assert rv.get() == ["t9", 1]
    assert calls == ["t9"] and rv.resolved


# ----------------------------------------------- integration: peer fetch


def test_peer_fetch_between_workers_exact_values():
    c = Client(transport="proc", workers=2, heartbeat_s=HB,
               inline_bytes=1024, steal_n=1)
    try:
        # slow producers force both workers to participate, so at least
        # one dependency of every sink lives on the OTHER worker
        bigs = [c.submit(lambda i=i: time.sleep(0.3) or bytes([i]) * BIG,
                         key=f"big{i}") for i in range(4)]
        c.gather(bigs)
        sums = [c.submit(
            (lambda *vs: hashlib.md5(b"".join(vs)).hexdigest()),
            *bigs, key=f"sum{i}") for i in range(4)]
        expect = hashlib.md5(
            b"".join(bytes([j]) * BIG for j in range(4))).hexdigest()
        for f in sums:
            assert f.result(timeout=60) == expect
        eng = c.engine
        # the hub tracked locations, the workers moved the bytes directly
        assert eng.xfer_totals["peer"][0] > 0, "no peer-path fetch happened"
        assert eng.xfer_totals["peer"][1] > BIG
        assert eng.xfer_lost_total == 0
        # attribution: unsampled xfer trace events match the totals
        n_ev = sum(1 for e in eng.tracer.events if e.event == XFER)
        n_tot = sum(v[0] for v in eng.xfer_totals.values())
        assert n_ev == n_tot > 0
        # a big result itself materializes through the lazy handle
        assert bigs[2].result(timeout=60) == bytes([2]) * BIG
    finally:
        c.close()


def test_small_values_stay_inline_no_locations():
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB)
    for i in range(8):
        eng.submit(f"s{i}", lambda i=i: i * 3)
    rep = eng.run()
    assert sorted(r.value for r in rep.results.values()) == \
        [i * 3 for i in range(8)]
    assert eng.backend.door.locations == {}
    assert eng.xfer_totals["peer"][0] == eng.xfer_totals["hub"][0] == 0


def test_spilled_value_served_by_hub():
    # a single worker with a tiny byte budget: producing big1 evicts big0
    # (replicated to the hub by Spill), so the consumer's fetch of big0
    # must come back over the hub path — and still be exact
    c = Client(transport="proc", workers=1, heartbeat_s=HB,
               inline_bytes=1024, spill_bytes=4096, steal_n=1)
    try:
        b0 = c.submit(lambda: b"a" * BIG, key="big0")
        b1 = c.submit(lambda: b"b" * BIG, key="big1")
        cons = c.submit(lambda x, y: (hashlib.md5(x).hexdigest(),
                                      hashlib.md5(y).hexdigest()),
                        b0, b1, key="cons")
        assert cons.result(timeout=60) == (
            hashlib.md5(b"a" * BIG).hexdigest(),
            hashlib.md5(b"b" * BIG).hexdigest())
        assert c.engine.xfer_totals["hub"][0] >= 1, \
            "spilled value did not travel the hub path"
        assert c.engine.xfer_lost_total == 0
    finally:
        c.close()


def test_engine_run_materializes_remote_values_in_report():
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB,
                 inline_bytes=1024)
    for i in range(3):
        eng.submit(f"big{i}", lambda i=i: bytes([i]) * BIG)
    rep = eng.run()
    for i in range(3):
        v = rep.results[f"big{i}"].value
        assert not isinstance(v, RemoteValue)
        assert v == bytes([i]) * BIG


# --------------------------------------- integration: SIGKILL + recompute


def test_producer_sigkill_recomputes_lost_value(tmp_path):
    """Kill the producer AFTER its big result completed but BEFORE any
    dependent fetched it: the only copy dies with the process, the
    consumer reports `__xfer_lost__`, and the engine recomputes the
    value from the task's packed call — zero loss, exact bytes."""
    pidfile = str(tmp_path / "producer.pid")
    flag = str(tmp_path / "gate.flag")
    c = Client(transport="proc", workers=2, heartbeat_s=HB,
               inline_bytes=1024, steal_n=1)
    try:
        big = c.submit(
            lambda p=pidfile: (open(p, "w").write(str(os.getpid())),
                               b"z" * BIG)[1], key="big")
        # the gate spins until the kill landed, so the consumer cannot
        # run (and cache the value) before the producer dies
        gate = c.submit(
            lambda f=flag: [time.sleep(0.02)
                            for _ in range(3000) if not os.path.exists(f)]
            and None, key="gate")
        cons = c.submit(lambda b, g: hashlib.md5(b).hexdigest(), big, gate,
                        key="cons")
        c._ensure_running()           # dispatch starts without a waiter
        _wait(lambda: os.path.exists(pidfile), what="producer pid")
        _wait(big.done, what="big terminal")
        pid = int(open(pidfile).read())
        os.kill(pid, signal.SIGKILL)
        _wait(lambda: not _pid_alive(pid), what="producer death")
        open(flag, "w").close()                    # release the gate
        assert cons.result(timeout=120) == \
            hashlib.md5(b"z" * BIG).hexdigest()
        assert c.engine.xfer_lost_total >= 1, \
            "consumer never hit the lost-value recompute path"
    finally:
        c.close()


# ------------------------------------------------- prune regression


def _populated_proc_engine(shards=1):
    eng = Engine(transport="proc", workers=2, shards=shards,
                 heartbeat_s=HB, inline_bytes=1024)
    for i in range(4):
        eng.submit(f"big{i}", lambda i=i: bytes([i]) * BIG)
    rep = eng.run()
    assert len(rep.results) == 4
    return eng


@pytest.mark.parametrize("shards", [1, 2])
def test_prune_terminal_evicts_data_plane_stores(shards):
    """Regression: pruned sessions must not leak payload bytes — every
    data-plane table on the front door (values from exit-flush spills,
    locations, early spills) is evicted along with the task records."""
    eng = _populated_proc_engine(shards=shards)
    door = eng.backend.door
    # exit flush replicated the big payloads hub-side; locations tracked
    assert door.values and door.locations
    door.early_spills["phantom"] = "stale-payload"
    eng.backend.prune_terminal(keep=("big1",))
    assert set(door.values) <= {"big1"}
    assert set(door.locations) <= {"big1"}
    assert door.early_spills == {}
    eng.backend.prune_terminal()
    assert door.values == {} and door.locations == {}


def test_engine_prune_respects_pinned_values():
    eng = _populated_proc_engine()
    eng.pin("big3")
    eng.prune_terminal()
    door = eng.backend.door
    assert set(door.values) == {"big3"}
    assert set(door.locations) == {"big3"}
