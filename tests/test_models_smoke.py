"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward + one train step on CPU, asserting shapes + no NaNs; decode-path
consistency against the full forward for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.configs import ARCH_IDS, RunConfig, get_config
from repro.models.common import Options, param_count
from repro.models.model import build_model
from repro.optim.adamw import init_opt
from repro.runtime.train_step import make_train_step

# model forward/train smoke is minutes-long on CPU; the scheduler core must
# give fast signal without it (CI runs -m "not slow")
pytestmark = pytest.mark.slow

OPTS = Options(q_block=32, kv_block=32, moe_group=64)


def _splice(big, small):
    difs = [i for i, (a, b) in enumerate(zip(big.shape, small.shape))
            if a != b]
    if not difs:
        return small.astype(big.dtype)
    ax = difs[0]
    idx = tuple(slice(None) if i != ax else slice(0, small.shape[ax])
                for i in range(big.ndim))
    return big.at[idx].set(small.astype(big.dtype))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_smoke(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    assert param_count(params) > 0
    B, S = 2, 64
    batch = tiny_batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"NaN logits in {name}"
    assert jnp.isfinite(jnp.asarray(aux)).all()


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_smoke(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    rc = RunConfig(total_steps=10, warmup_steps=2)
    opt = init_opt(params, rc)
    batch = tiny_batch(cfg, 2, 64)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    step = jax.jit(make_train_step(model, rc))
    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", ["qwen2.5-32b", "gemma2-2b",
                                  "deepseek-v2-lite-16b", "whisper-base"])
def test_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = tiny_batch(cfg, B, S)
    lg, cache, _ = jax.jit(
        lambda p, b: model.forward(p, b, mode="prefill"))(params, batch)
    cache_full = model.init_cache(B, S + 8)
    cache_full = jax.tree_util.tree_map(_splice, cache_full, cache)
    tok1 = jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)
    lg2, _ = jax.jit(model.decode_step)(
        params, tok1, jnp.full((B,), S, jnp.int32), cache_full)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], tok1[:, None]], 1)
    if cfg.mrope:
        b2["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1))
    lf, _ = jax.jit(lambda p, b: model.forward(p, b))(params, b2)
    err = float(jnp.max(jnp.abs(lf[:, -1].astype(jnp.float32)
                                - lg2.astype(jnp.float32))))
    assert err < 0.15, err


@pytest.mark.parametrize("name", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_recurrent_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 48
    batch = tiny_batch(cfg, B, S)
    logits, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, batch["tokens"][:, t],
                         jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1).astype(jnp.float32)
                                - logits.astype(jnp.float32))))
    assert err < 0.15, err


def test_gemma_local_global_masking():
    """A token beyond the sliding window must still be reachable via global
    layers but local layers must mask it — verify logits differ when a
    long-range token changes only within-window vs out-of-window."""
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 64
    batch = tiny_batch(cfg, B, S)
    base, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    toks2 = batch["tokens"].at[0, 0].set((batch["tokens"][0, 0] + 1)
                                         % cfg.vocab_size)
    out2, _ = jax.jit(lambda p, b: model.forward(p, b))(
        params, {"tokens": toks2})
    # token 0 is outside the window (16) of position 63 but global layers
    # still propagate information: logits at the last position must change
    assert float(jnp.max(jnp.abs(base[0, -1] - out2[0, -1]))) > 0
