"""Observability subsystem tests: metrics registry semantics, Prometheus
exposition, live instrumentation over a running engine, the streaming
stats endpoint under load, Chrome-trace export, and the trace-accounting
satellites (rpc-sampling scale-up, ring-buffer drop counting, incomplete
request latencies)."""
import json
import re
import threading
import urllib.error
import urllib.request

import pytest

from repro.client import Client
from repro.core.engine import (COMPLETED, REQ_DONE, REQ_ENQUEUED,
                               REQ_REJECTED, RPC, RUN_END, RUN_START, Engine,
                               LatencyReport, ManualClock, OverheadReport,
                               TraceRecorder)
from repro.core.obs import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                            MetricsRegistry, StatsServer, instrument,
                            to_chrome_trace)
from repro.core.obs import top as obs_top


# ------------------------------------------------------ metrics registry


def test_registry_get_or_create_is_keyed_by_name_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help")
    assert reg.counter("x_total") is a                 # same key, same obj
    b = reg.counter("x_total", labels={"op": "steal"})
    assert b is not a                                  # labels split series
    assert reg.counter("x_total", labels={"op": "steal"}) is b
    a.inc()
    a.inc(4)
    assert a.value == 5 and b.value == 0


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("thing")
    with pytest.raises(TypeError):
        reg.gauge("thing")
    with pytest.raises(TypeError):
        reg.histogram("thing")


def test_callback_instruments_read_at_scrape_and_never_raise():
    reg = MetricsRegistry()
    state = {"n": 7}
    c = reg.counter("cb_total", fn=lambda: state["n"])
    assert c.value == 7
    state["n"] = 9
    assert c.value == 9                                # read live, not cached
    with pytest.raises(RuntimeError):
        c.inc()                                        # owner already counts
    boom = reg.gauge("boom", fn=lambda: 1 / 0)
    assert boom.value == 0                             # monitoring never raises


def test_gauge_set_inc_dec():
    g = Gauge("g")
    g.set(10)
    g.inc(5)
    g.dec(2)
    assert g.value == 13


def test_histogram_observe_quantile_snapshot():
    h = Histogram("lat", buckets=(0.001, 0.01, 0.1, 1.0))
    for v in (0.0005, 0.005, 0.005, 0.05, 0.5):
        h.observe(v)
    assert h.count == 5 and abs(h.sum - 0.5605) < 1e-9
    snap = h.snapshot()
    assert snap["buckets"]["0.001"] == 1               # cumulative counts
    assert snap["buckets"]["0.01"] == 3
    assert snap["buckets"]["1.0"] == 5
    assert snap["buckets"]["+Inf"] == 5
    q50, q95 = h.quantile(0.5), h.quantile(0.95)
    assert 0.001 <= q50 <= 0.01                        # median in 2nd bucket
    assert q95 <= 1.0 and q95 >= q50
    assert Histogram("empty").quantile(0.5) == 0.0


def test_histogram_default_buckets_span_us_to_seconds():
    assert LATENCY_BUCKETS[0] == 1e-6 and LATENCY_BUCKETS[-1] == 10.0
    assert list(LATENCY_BUCKETS) == sorted(LATENCY_BUCKETS)


def test_dump_keys_are_label_qualified():
    reg = MetricsRegistry()
    reg.counter("a_total", labels={"op": "steal"}).inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("h", labels={"k": "v"}).observe(0.5)
    d = reg.dump()
    assert d["counters"]['a_total{op="steal"}'] == 3
    assert d["gauges"]["depth"] == 2
    assert d["histograms"]['h{k="v"}']["count"] == 1


# prometheus text format 0.0.4: sample lines are
#   name{label="v",...} value   |   name value
_PROM_SAMPLE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})? '
    r'(-?\d+(\.\d+)?([eE][+-]?\d+)?|[+-]Inf|NaN)$')


def test_prometheus_exposition_parses():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests served", labels={"op": "steal"}).inc(3)
    reg.counter("req_total", labels={"op": "create"}).inc(1)
    reg.gauge("depth", "queue depth").set(4)
    reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1)).observe(0.05)
    text = reg.prometheus()
    assert text.endswith("\n")
    samples = 0
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("#"):
            assert re.match(r"^# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* ", line)
            continue
        assert _PROM_SAMPLE.match(line), f"bad sample line: {line!r}"
        samples += 1
    # HELP/TYPE emitted once per family even with two req_total series
    assert text.count("# TYPE req_total counter") == 1
    # histogram expands to cumulative buckets + _sum/_count
    assert 'lat_seconds_bucket{le="0.01"} 0' in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    assert samples >= 8


def test_prometheus_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("esc_total", labels={"p": 'a"b\\c\nd'}).inc()
    text = reg.prometheus()
    assert r'p="a\"b\\c\nd"' in text


# ------------------------------------------------- instrumented engine


def test_instrumented_batch_engine_reports_live_counts():
    eng = Engine(workers=4, steal_n=4)
    for i in range(200):
        eng.submit(f"t{i}", meta={"x": i})
    reg = instrument(engine=eng)
    eng.run(lambda name, meta: (True, meta["x"] * 2))
    d = reg.dump()
    assert d["counters"]["repro_tasks_completed_total"] == 200
    assert d["counters"]["repro_tasks_failed_total"] == 0
    assert d["counters"]["repro_worker_deaths_total"] == 0
    assert d["counters"]["repro_trace_events_total"] > 0
    assert d["gauges"]["repro_ready_depth"] == 0
    # rpc histograms observed at the backend's sampled timing sites
    rpc = {k: v for k, v in d["histograms"].items()
           if k.startswith("repro_rpc_latency_seconds")}
    assert rpc and all(v["count"] > 0 for v in rpc.values())
    # per-worker table the server view is built from
    ws = eng.worker_stats()
    assert sum(s["done"] for s in ws.values()) == 200
    assert all(s["alive"] for s in ws.values())
    assert eng.tasks_done_total() == 200


def test_instrument_is_idempotent_and_chains():
    eng = Engine(workers=1)
    reg = instrument(engine=eng)
    assert instrument(reg, engine=eng) is reg          # re-instrument: no-op
    m = eng.backend.metrics
    assert m is not None
    instrument(reg, engine=eng)
    assert eng.backend.metrics is m                    # not replaced


def test_failed_tasks_count_in_failed_not_completed():
    eng = Engine(workers=2)
    for i in range(20):
        eng.submit(f"t{i}", meta={"x": i})
    reg = instrument(engine=eng)
    eng.run(lambda name, meta: (name != "t7", meta["x"]))
    d = reg.dump()
    assert d["counters"]["repro_tasks_failed_total"] == 1
    assert d["counters"]["repro_tasks_completed_total"] == 19


# --------------------------------- satellite: rpc sampling + ring drops


def test_rpc_sampling_scales_report_and_thins_metrics():
    tracer = TraceRecorder(rpc_sample=4)
    eng = Engine(workers=4, steal_n=2, tracer=tracer)
    reg = instrument(engine=eng)      # BEFORE submit: creates are rpcs too
    for i in range(200):
        eng.submit(f"t{i}", meta={"x": i})
    rep = eng.run(lambda name, meta: (True, meta["x"]))
    ov = rep.overhead()
    recorded = len(tracer.of(RPC))
    assert 0 < recorded < tracer.rpc_seen              # thinned 4:1-ish
    # the report scales the sampled totals back up to the true call count
    assert ov.n_rpc == tracer.rpc_seen
    # the rpc histograms ride the SAME sampling: one observation per
    # recorded event, not per call
    d = reg.dump()
    observed = sum(v["count"] for k, v in d["histograms"].items()
                   if k.startswith("repro_rpc_latency_seconds"))
    assert observed == recorded


def test_rpc_scale_up_excludes_hop_ops():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    # 2 sampled end-to-end round-trips out of 8 seen...
    tr.rpc_sample = 4
    for _ in range(8):
        if tr.sample_rpc():
            tr.emit(RPC, op="complete_steal", dt=1e-3)
    # ...plus forwarding-tree hops, emitted directly (no sample_rpc call)
    tr.emit(RPC, op="hop:L1", dt=5e-4)
    tr.emit(RPC, op="hop:L1", dt=5e-4)
    ov = OverheadReport.from_trace(tr)
    assert ov.n_rpc == 8                               # scaled to rpc_seen
    assert abs(ov.rpc_s - 8 * 1e-3) < 1e-9             # 2 recorded x 8/2
    # hops appear in the per-op breakdown but not in the scaled totals
    assert ov.rpc_by_op["hop:L1"][0] == 2
    assert ov.rpc_by_op["complete_steal"][0] == 2


def test_ring_buffer_drop_count_under_concurrent_emit():
    tr = TraceRecorder(max_events=100)
    threads = [threading.Thread(
        target=lambda: [tr.emit(COMPLETED, task="t") for _ in range(500)])
        for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.n_emitted == 4000                        # no lost increments
    assert len(tr.events) == 100
    assert tr.dropped == 3900


def test_overhead_summary_carries_emitted_and_dropped():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock, max_events=4)
    for i in range(10):
        tr.emit(COMPLETED, task=f"t{i}")
    s = OverheadReport.from_trace(tr).summary()
    assert s["n_emitted"] == 10 and s["dropped"] == 6
    # unbounded recorder: dropped stays 0
    tr2 = TraceRecorder(clock=clock)
    tr2.emit(COMPLETED, task="t")
    s2 = OverheadReport.from_trace(tr2).summary()
    assert s2["n_emitted"] == 1 and s2["dropped"] == 0


def test_latency_report_skips_unstamped_req_done():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    tr.emit(REQ_DONE, task="r0", latency_s=0.010, ok=True)
    tr.emit(REQ_DONE, task="r1")                       # partner evicted
    tr.emit(REQ_DONE, task="r2", latency_s=0.030, ok=True)
    rep = LatencyReport.from_trace(tr)
    assert rep.n_requests == 2 and rep.n_incomplete == 1
    assert abs(rep.mean_s - 0.020) < 1e-9              # no 0.0 dragging p50
    assert rep.summary()["n_incomplete"] == 1


# ------------------------------------------------------- stats endpoint


def _get(url, timeout=10.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        ctype = resp.headers.get("Content-Type", "")
        return resp.read().decode(), ctype


def test_stats_server_live_under_load():
    with Client(scheduler="dwork", workers=4, shards=2) as c:
        srv = c.stats_server()
        fe = c.serve(lambda ps: [p * 2 for p in ps], max_wait_s=0.002)
        fs = [c.submit(lambda x=x: x * x) for x in range(300)]
        reqs = [fe.submit(i) for i in range(50)]

        # scrape while the engine is running — it must keep dispatching
        body, ctype = _get(srv.url + "/stats")
        assert ctype.startswith("application/json")
        mid = json.loads(body)
        assert mid["engine"]["live_workers"] == 4
        assert mid["rates"]["window_s"] is not None    # baselined at start()

        assert c.gather(fs) == [x * x for x in range(300)]
        assert all(r.wait(30.0) and r.value == i * 2
                   for i, r in enumerate(reqs))

        stats = json.loads(_get(srv.url + "/stats")[0])
        assert stats["engine"]["tasks_done"] >= 300
        assert stats["engine"]["tasks_failed"] == 0
        assert stats["engine"]["shard_ready_depth"] == [0, 0]
        assert stats["engine"]["trace"]["n_emitted"] > 0
        assert len(stats["workers"]) == 4
        for row in stats["workers"].values():
            assert row["alive"] and 0.0 <= row["busy_frac"] <= 1.0
        assert stats["serving"] and stats["serving"][0]["n_requests"] >= 0

        health = json.loads(_get(srv.url + "/health")[0])
        assert health["ok"] and health["live_workers"] == 4

        body, ctype = _get(srv.url + "/metrics")
        assert "version=0.0.4" in ctype
        for line in body.splitlines():
            if line and not line.startswith("#"):
                assert _PROM_SAMPLE.match(line), f"bad: {line!r}"
        assert "repro_live_workers 4" in body
        assert "repro_futures_submitted_total" in body

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404
    # client close stops the server: the port no longer answers
    with pytest.raises(OSError):
        _get(srv.url + "/health", timeout=0.5)


def test_stats_server_windowed_rates_diff_between_scrapes():
    eng = Engine(workers=2, resident=True)
    eng.start()
    try:
        reg = instrument(engine=eng)
        with StatsServer(reg, engine=eng) as srv:
            for i in range(100):
                eng.submit(f"t{i}", fn=lambda: None)
            assert eng.drain(timeout=30)
            s1 = json.loads(_get(srv.url + "/stats")[0])
            assert s1["rates"]["tasks_per_s"] > 0      # work since baseline
            s2 = json.loads(_get(srv.url + "/stats")[0])
            assert s2["rates"]["tasks_per_s"] == 0.0   # nothing in window
            assert s2["engine"]["tasks_done"] == 100
    finally:
        eng.shutdown()


def test_stats_server_start_stop_idempotent():
    srv = StatsServer(MetricsRegistry())
    assert srv.start() is srv and srv.start() is srv
    port = srv.port
    assert port != 0
    srv.stop()
    srv.stop()                                         # double stop is fine


# -------------------------------------------------------- chrome trace


def test_chrome_trace_structure_and_worker_lanes(tmp_path):
    with Client(scheduler="dwork", workers=2) as c:
        fe = c.serve(lambda ps: [p + 1 for p in ps], max_wait_s=0.002)
        fs = [c.submit(lambda x=x: x) for x in range(40)]
        reqs = [fe.submit(i) for i in range(10)]
        c.gather(fs)
        assert all(r.wait(30.0) for r in reqs)
        report = c.close()
    out = tmp_path / "t.trace.json"
    doc = report.trace.to_chrome_trace(str(out))
    loaded = json.loads(out.read_text())
    assert loaded == doc and doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    lanes = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "w0" in lanes and "w1" in lanes and "requests" in lanes
    assert lanes["w0"] < lanes["w1"] < lanes["requests"]  # pool order first
    # every task execution is an X span on its worker's lane
    spans = [e for e in evs if e["ph"] == "X" and e.get("cat") == "task"]
    assert spans
    assert {e["tid"] for e in spans} <= {lanes["w0"], lanes["w1"]}
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0
    # serving requests are async b/e pairs balanced per id
    begins = [e["id"] for e in evs if e["ph"] == "b"]
    ends = [e["id"] for e in evs if e["ph"] == "e"]
    assert sorted(begins) == sorted(ends) and len(ends) == 10
    for e in evs:
        assert "pid" in e and "tid" in e


def test_chrome_trace_synthesizes_begin_for_evicted_enqueue():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    clock.advance(1.0)
    tr.emit(RUN_START, task="a", worker="w0")          # trace epoch: t=1.0
    clock.advance(0.5)
    tr.emit(RUN_END, task="a", worker="w0")
    tr.emit(REQ_DONE, task="r9", latency_s=0.25, ok=True)  # fits the window
    tr.emit(REQ_DONE, task="r7", latency_s=3.0, ok=True)   # predates epoch
    tr.emit(REQ_DONE, task="r8")                       # unstamped: skipped
    doc = to_chrome_trace(tr)
    begins = {e["id"]: e for e in doc["traceEvents"] if e["ph"] == "b"}
    ends = {e["id"]: e for e in doc["traceEvents"] if e["ph"] == "e"}
    assert set(begins) == set(ends) == {"r9", "r7"}
    # a latency inside the retained window synthesizes begin at t - lat
    assert abs(begins["r9"]["ts"] - 0.25 * 1e6) < 1.0
    assert abs((ends["r9"]["ts"] - begins["r9"]["ts"]) - 0.25 * 1e6) < 1.0
    # a request older than the window clamps at the trace epoch — it must
    # never render at a negative timestamp (Perfetto misplaces the span)
    assert begins["r7"]["ts"] == 0.0


def test_chrome_trace_rpc_and_worker_events():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    tr.emit(RUN_START, task="a", worker="w0")
    clock.advance(0.002)
    tr.emit(RUN_END, task="a", worker="w0")
    tr.emit(RPC, op="complete_steal", dt=1e-3, n=4)
    tr.emit(RPC, op="hop:L1", dt=5e-4)
    doc = to_chrome_trace(tr)
    evs = doc["traceEvents"]
    lanes = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert set(lanes) == {"w0", "rpc", "hop:L1"}
    task = next(e for e in evs if e.get("cat") == "task")
    assert abs(task["dur"] - 2000.0) < 1.0             # 2ms in us
    rpc = next(e for e in evs if e.get("cat") == "rpc"
               and e["name"] == "complete_steal")
    assert rpc["tid"] == lanes["rpc"] and rpc["args"]["n"] == 4
    hop = next(e for e in evs if e["name"] == "hop:L1")
    assert hop["tid"] == lanes["hop:L1"]


# -------------------------------------------------- per-tenant slicing


def test_frontend_tenant_label_rides_req_events_and_snapshots():
    with Client(scheduler="dwork", workers=2, transport="thread") as c:
        fe = c.serve(lambda ps: [p * 2 for p in ps], max_wait_s=0.002)
        fe.snapshot()                                  # arm monitoring
        reqs = [fe.submit(i, tenant=("acme" if i % 2 else "globex"))
                for i in range(20)]
        reqs.append(fe.submit(99))                     # untenanted rides along
        fe.flush()
        assert all(r.wait(30.0) for r in reqs)
        # the label reaches the REQ_* trace events
        tr = c.engine.tracer
        enq = [e for e in tr.of(REQ_ENQUEUED) if "tenant" in e.extra]
        done = [e for e in tr.of(REQ_DONE) if "tenant" in e.extra]
        assert len(enq) == 20 and len(done) == 20
        assert {e.extra["tenant"] for e in done} == {"acme", "globex"}
        # windowed snapshot slices per tenant; untenanted stays top-level
        rep = fe.snapshot()
        assert rep.n_requests == 21
        assert sorted(rep.by_tenant) == ["acme", "globex"]
        for t in ("acme", "globex"):
            sub = rep.by_tenant[t]
            assert sub.n_requests == 10 and sub.n_failed == 0
            assert sub.p50_s > 0 and sub.p99_s >= sub.p50_s
        summ = rep.summary()
        assert sorted(summ["tenants"]) == ["acme", "globex"]
        assert summ["tenants"]["acme"]["latency_ms"]["p95"] >= 0
        # post-hoc trace accounting agrees with the live windows
        lr = LatencyReport.from_trace(tr)
        assert lr.by_tenant["acme"].n_requests == 10
        assert lr.by_tenant["globex"].n_requests == 10
        # and the summary renders in the dashboard
        text = obs_top.render({"serving": [summ]})
        assert "tenant acme" in text and "tenant globex" in text


def test_tenant_latency_histograms_in_prometheus():
    with Client(scheduler="dwork", workers=2, transport="thread") as c:
        srv = c.stats_server()
        fe = c.serve(lambda ps: [p + 1 for p in ps], max_wait_s=0.002)
        reqs = [fe.submit(i, tenant="acme") for i in range(6)]
        reqs += [fe.submit(i) for i in range(4)]
        fe.flush()
        assert all(r.wait(30.0) for r in reqs)
        body, _ = _get(srv.url + "/metrics")
        assert ('repro_request_latency_seconds_count'
                '{frontend="0",tenant="acme"} 6') in body
        # the unlabelled family still counts every request
        assert ('repro_request_latency_seconds_count'
                '{frontend="0"} 10') in body
        for line in body.splitlines():
            if line and not line.startswith("#"):
                assert _PROM_SAMPLE.match(line), f"bad: {line!r}"


def test_rejected_requests_count_into_tenant_slice():
    with Client(scheduler="dwork", workers=1, transport="thread") as c:
        fe = c.serve(lambda ps: ps, max_queue=1, policy="reject",
                     max_wait_s=10.0)
        fe.snapshot()                                  # arm monitoring
        r0 = fe.submit(0, tenant="acme")               # fills the queue
        with pytest.raises(Exception):
            fe.submit(1, tenant="acme")                # bounced
        rej = [e for e in c.engine.tracer.of(REQ_REJECTED)
               if e.extra.get("tenant") == "acme"]
        assert len(rej) == 1
        fe.flush()
        assert r0.wait(30.0)
        rep = fe.snapshot()
        assert rep.by_tenant["acme"].n_rejected == 1
        assert rep.by_tenant["acme"].n_requests == 1


def test_client_submit_tenant_lands_in_task_meta():
    with Client(scheduler="dwork", workers=1) as c:
        f = c.submit(lambda: 1, tenant="acme")
        g = c.submit(lambda: 2)
        assert c.gather([f, g]) == [1, 2]
        assert c.engine.tasks[f.name].meta == {"tenant": "acme"}
        assert c.engine.tasks[g.name].meta == {}


# ----------------------------------------------------------- dashboard


def test_top_render_and_fetch():
    eng = Engine(workers=2, resident=True)
    eng.start()
    try:
        reg = instrument(engine=eng)
        with StatsServer(reg, engine=eng) as srv:
            for i in range(20):
                eng.submit(f"t{i}", fn=lambda: None)
            assert eng.drain(timeout=30)
            stats = obs_top.fetch(srv.url)
            text = obs_top.render(stats)
            assert "WORKER" in text and "w0" in text and "w1" in text
            assert "tasks/s" in text
    finally:
        eng.shutdown()
    assert isinstance(obs_top.render({}), str)         # degrade, not crash
