"""Table 4 / Fig. 5 reproduction: per-component overhead breakdown.

Columns mirrored from the paper: job-step launch, alloc, dwork per-task
RTT, mpi-list sync latency, Python import cost, dwork connection setup —
paper (Summit) values side-by-side with our measured (this container)
values, plus the Fig. 5 style time-share breakdown per task size.
"""
from __future__ import annotations

import subprocess
import sys
import time

from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.dwork.client import TCPServer, TCPTransport
from repro.core.metg import (PAPER_ALLOC, PAPER_DWORK_RTT, PAPER_JSRUN,
                             PAPER_MPILIST_SYNC, METGModel)


def measure_python_import() -> float:
    t0 = time.perf_counter()
    subprocess.run([sys.executable, "-c", "import numpy"], check=True)
    return time.perf_counter() - t0


def measure_connection_setup(n: int = 20) -> float:
    srv = TaskServer()
    tcp = TCPServer(("127.0.0.1", 0), srv)
    tcp.serve_background()
    t0 = time.perf_counter()
    for _ in range(n):
        tr = TCPTransport(*tcp.server_address)
        tr.close()
    dt = (time.perf_counter() - t0) / n
    tcp.shutdown()
    return dt


def run(quick: bool = True) -> dict:
    from benchmarks.metg import (measure_dwork_rtt, measure_mpilist_sigma,
                                 measure_pmake_launch)
    rtt = measure_dwork_rtt(300 if quick else 2000)
    table4 = {
        "jsrun_launch_s": {"paper@864": PAPER_JSRUN[864],
                           "ours_popen": round(measure_pmake_launch(8), 4)},
        "alloc_s": {"paper": PAPER_ALLOC, "ours": "n/a (no GPU alloc)"},
        "dwork_rtt_us": {"paper": PAPER_DWORK_RTT * 1e6,
                         "ours_inproc": round(rtt["inproc_rtt_s"] * 1e6, 1),
                         "ours_tcp": round(rtt["tcp_rtt_s"] * 1e6, 1)},
        "mpilist_sync_s_per_1024": {
            "paper@864": PAPER_MPILIST_SYNC[864],
            "ours_sigma": round(measure_mpilist_sigma(8, 300), 6)},
        "python_import_s": {"paper@864": 2.82,
                            "ours_numpy": round(measure_python_import(), 2)},
        "dwork_connection_s": {"paper@864": 2.74,
                               "ours_tcp": round(measure_connection_setup(), 4)},
    }

    # Fig 5: time-share pies -> fractions per (tool, task_size) at 864 ranks
    model = METGModel.from_paper()
    shares = {}
    for tool in ("pmake", "dwork", "mpi-list"):
        overhead = model.metg(tool, 864)
        shares[tool] = {
            f"{t:g}s": {"compute": round(t / (t + overhead), 3),
                        "overhead": round(overhead / (t + overhead), 3)}
            for t in (0.01, 0.1, 1.0, 10.0, 100.0)}
    return {"table4": table4, "fig5_time_shares@864": shares}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
