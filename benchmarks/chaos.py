"""Chaos harness: a seeded mixed workload under combined injected faults,
emitted as BENCH_chaos.json — the repo's robustness gate.

Two phases, both seeded end-to-end:

  * **serving+retry** — a futures DAG and open serving traffic share one
    resident engine while a worker is killed mid-stream and a seeded
    fraction of task executions fail transiently (`fail_first_k`).  The
    `RetryPolicy` must absorb every transient failure (all futures and
    requests resolve with correct values) within budget: with k=1 and
    max_attempts=3, retries == distinct affected tasks, never more.
  * **crash+recover** — a journaled batch campaign is killed mid-DAG
    (every worker dies -> stall, the in-memory universe is gone), then
    `Engine.recover(journal_dir)` rebuilds from the write-ahead journal
    and completes the workload.  Asserted: zero task loss (phase-1 +
    phase-2 executions cover the universe exactly) and zero
    double-completion (the two execution sets are disjoint).

Modes:
    (default)   run both phases -> BENCH_chaos.json (+ stdout)
    --check     re-run and assert every invariant; wall-clock compared
                against the committed baseline (generous tolerance — this
                gate is about correctness under faults, not speed)
    --artifacts DIR   keep the recovered journal + a listing under DIR
                (CI uploads it as the sample recovered-journal artifact)
"""
from __future__ import annotations

import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

from repro.client import Client
from repro.core.engine import (Engine, FaultPlan, Journal, RetryPolicy)

REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "BENCH_chaos.json"

N_FUTURES = 240
N_REQUESTS = 200
N_RECOVERY_TASKS = 400
FAIL_RATE = 0.3                # seeded fraction of tasks failing once
MAX_ATTEMPTS = 3               # retry budget (> k=1, so all must recover)
KILL_AFTER_STEALS = 20         # w3 dies mid-stream in the serving phase
CHECK_WALL_TOLERANCE = 4.0     # correctness gate: loose wall-clock bound


def _calibrate_us() -> float:
    """Machine-speed probe (same estimator as the other benchmark gates)."""
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        total = 0
        for i in range(100000):
            total += i * i
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ------------------------------------------------------ phase 1: serving


def phase_serving(seed: int = 0) -> dict:
    """Futures DAG + serving traffic on one engine, under a worker kill
    and seeded transient failures absorbed by RetryPolicy."""
    plan = (FaultPlan(seed).fail_first_k(1, rate=FAIL_RATE)
            .kill_worker("w3", after_steals=KILL_AFTER_STEALS))
    t0 = time.perf_counter()
    with Client(workers=4, transport="thread", faults=plan,
                retry=RetryPolicy(max_attempts=MAX_ATTEMPTS, backoff=0.0,
                                  seed=seed)) as c:
        fe = c.serve(lambda ps: [p * 3 + 1 for p in ps],
                     max_queue=4096, max_batch=16, max_wait_s=0.002,
                     per_request_s0=2e-6)
        # chained futures DAG: stable task names (key=) so the seeded
        # fault draws are identical run to run
        futs: list = []
        for i in range(N_FUTURES):
            if i % 3 and futs:
                futs.append(c.submit(lambda a, i=i: a + i, futs[-1],
                                     key=f"chaos{i}"))
            else:
                futs.append(c.submit(lambda i=i: i * 2, key=f"chaos{i}"))
        reqs = [fe.submit(i, timeout=None if i % 5 else 60.0)
                for i in range(N_REQUESTS)]
        values = c.gather(futs)
        for r in reqs:
            if not r.wait(60):
                raise AssertionError(f"request lost: {r}")
        fe.flush()
        # ---------------- invariants, checked while the engine is live
        expect = []
        for i in range(N_FUTURES):
            expect.append(expect[-1] + i if i % 3 and expect else i * 2)
        if values != expect:
            raise AssertionError("future values corrupted under faults")
        bad = sum(1 for i, r in enumerate(reqs)
                  if not r.ok or r.value != 3 * i + 1)
        timed_out = sum(1 for r in reqs if r.timed_out)
        if bad or timed_out:
            raise AssertionError(
                f"serving loss under faults: bad={bad} timeouts={timed_out}")
        retries = c.engine.retries_total
        deaths = c.engine.worker_deaths
        n_tasks = c.engine.tasks_done_total()
        rep = c.close()
    wall = time.perf_counter() - t0
    # retry budget: k=1 transient failure per affected task, so retries
    # can never exceed the task universe (futures + coalesced batches)
    if not (1 <= retries <= n_tasks):
        raise AssertionError(f"retry count out of budget: {retries} "
                             f"(tasks={n_tasks})")
    if deaths != 1:
        raise AssertionError(f"injected worker kill did not bite: {deaths}")
    ov = rep.overhead()
    return {
        "n_futures": N_FUTURES, "n_requests": N_REQUESTS,
        "fail_rate": FAIL_RATE, "retries": retries,
        "n_retried_events": ov.n_retried, "n_requeued": ov.n_requeued,
        "workers_killed": deaths, "wall_s": round(wall, 4),
    }


# ----------------------------------------------------- phase 2: recovery


def phase_recovery(seed: int = 0, keep_dir=None) -> dict:
    """Journaled batch campaign killed mid-DAG, then recovered from the
    write-ahead journal.  `keep_dir` preserves the recovered journal
    (CI artifact); otherwise it is deleted."""
    jdir = Path(keep_dir) if keep_dir is not None \
        else Path(tempfile.mkdtemp(prefix="chaos-journal-"))
    if jdir.exists() and any(jdir.iterdir()):
        shutil.rmtree(jdir)
    n = N_RECOVERY_TASKS
    universe = {f"t{i}" for i in range(n)}
    phase1: list = []
    phase2: list = []
    t0 = time.perf_counter()
    # the crash: every worker dies mid-campaign -> the run stalls and the
    # in-memory task tables are lost with the engine
    faults = (FaultPlan(seed).kill_worker("w0", after_steals=n // 8)
              .kill_worker("w1", after_steals=n // 8))
    eng = Engine(workers=2, transport="thread", journal=str(jdir),
                 faults=faults, max_idle_rounds=50)
    for i in range(n):
        deps = [f"t{i-1}"] if i % 4 else []      # chains of 4
        eng.submit(f"t{i}", deps=deps, meta={"i": i})
    rep1 = eng.run(lambda name, meta: phase1.append(name) or True)
    if not rep1.stalled:
        raise AssertionError("simulated crash did not stall the engine")
    done1 = set(rep1.completed)
    if not done1 or done1 >= universe:
        raise AssertionError(f"crash not mid-DAG: {len(done1)}/{n} done")

    st = Journal.replay(jdir)
    if st.completed != done1:
        raise AssertionError("journal lost terminal records across crash")

    eng2 = Engine.recover(str(jdir), workers=2, transport="thread")
    rep2 = eng2.run(lambda name, meta: phase2.append(name) or True)
    wall = time.perf_counter() - t0
    if rep2.stalled:
        raise AssertionError("recovery run stalled")
    # zero loss + zero double-completion
    if done1 | set(phase2) != universe:
        missing = universe - done1 - set(phase2)
        raise AssertionError(f"task loss across recovery: {missing}")
    dupes = done1 & set(phase2)
    if dupes:
        raise AssertionError(f"double-completion across recovery: {dupes}")
    st2 = Journal.replay(jdir)
    if len(st2.completed) != n or st2.pending():
        raise AssertionError(f"recovered journal inconsistent: "
                             f"{st2.summary()}")
    # compact so the kept artifact shows the checkpoint idiom too
    j = Journal(jdir)
    j.checkpoint()
    j.close()
    listing = sorted(f"{p.name} ({p.stat().st_size}B)"
                     for p in jdir.iterdir())
    out = {
        "n_tasks": n, "done_before_crash": len(done1),
        "recovered": len(phase2), "requeues_journaled": st2.requeues,
        "wall_s": round(wall, 4),
        "journal": {**Journal.replay(jdir).summary(), "files": listing},
    }
    if keep_dir is None:
        shutil.rmtree(jdir)
    return out


# ---------------------------------------------------------------- driver


def run(seed: int = 0, artifacts=None) -> dict:
    art = Path(artifacts) if artifacts else None
    if art is not None:
        art.mkdir(parents=True, exist_ok=True)
    serving = phase_serving(seed)
    recovery = phase_recovery(
        seed, keep_dir=(art / "recovered-journal") if art else None)
    out = {
        "seed": seed,
        "serving": serving,
        "recovery": recovery,
        "invariants": {
            "zero_task_loss": True,          # raised above otherwise
            "no_double_completion": True,
            "retries_within_budget": True,
            "zero_request_loss": True,
        },
        "wall_s": round(serving["wall_s"] + recovery["wall_s"], 4),
        "calibration_us": round(_calibrate_us(), 1),
    }
    if art is not None:
        (art / "journal_listing.txt").write_text(
            "\n".join(recovery["journal"]["files"]) + "\n")
        (art / "BENCH_chaos.json").write_text(json.dumps(out, indent=1))
    return out


def run_check(artifacts=None) -> int:
    """CI robustness gate: every invariant must hold under the seeded
    fault mix; wall clock only has to stay within a loose multiple of
    the committed baseline (scaled by machine speed)."""
    baseline = json.loads(BASELINE.read_text())
    scale = 1.0
    base_cal = baseline.get("calibration_us")
    if base_cal:
        scale = min(max(_calibrate_us() / base_cal, 1.0), 4.0)
    wall_limit = baseline["wall_s"] * CHECK_WALL_TOLERANCE * scale
    print(f"machine-speed scale vs baseline: {scale:.2f}x "
          f"(wall limit {wall_limit:.1f}s)")
    last_err = None
    for attempt in range(3):
        try:
            meas = run(baseline.get("seed", 0), artifacts=artifacts)
        except AssertionError as e:
            # a chaos invariant is deterministic under the seed: one
            # retry guards against environment flakes, not real bugs
            last_err = e
            print(f"attempt {attempt + 1}: INVARIANT FAILED: {e}",
                  file=sys.stderr)
            time.sleep(2)
            continue
        ok = meas["wall_s"] <= wall_limit
        print(f"chaos: retries={meas['serving']['retries']} "
              f"recovered={meas['recovery']['recovered']}"
              f"/{meas['recovery']['n_tasks']} "
              f"wall={meas['wall_s']:.2f}s (limit {wall_limit:.1f}s) "
              f"{'OK' if ok else 'TOO SLOW'}")
        if ok:
            return 0
        last_err = AssertionError(f"wall {meas['wall_s']} > {wall_limit}")
        time.sleep(2)
    print(f"chaos gate failed: {last_err}", file=sys.stderr)
    return 1


def _artifacts_arg(argv: list):
    if "--artifacts" in argv:
        return argv[argv.index("--artifacts") + 1]
    return None


if __name__ == "__main__":
    artifacts = _artifacts_arg(sys.argv)
    if "--check" in sys.argv:
        sys.exit(run_check(artifacts=artifacts))
    result = run(artifacts=artifacts)
    BASELINE.write_text(json.dumps(result, indent=1))
    print(json.dumps(result, indent=1))
    print(f"\nwrote {BASELINE}", file=sys.stderr)
