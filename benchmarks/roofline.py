"""§Roofline table generator: reads the dry-run result JSONs and emits the
per-(arch x shape) three-term roofline table (single-pod) plus the
multi-pod §Dry-run summary."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load(mesh: str = "16x16", tag: str = "") -> list:
    out = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for p in sorted(RESULTS.glob(f"*{suffix}")):
        if tag == "" and p.stem.count("__") > 2:
            continue                      # skip tagged perf variants
        out.append(json.loads(p.read_text()))
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(mesh: str = "16x16", tag: str = "") -> str:
    rows = ["| arch | shape | compute | memory | collective | bottleneck | "
            "useful/HLO flops | fit<16GB |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load(mesh, tag):
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                        f"SKIP | - | - |")
            continue
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                        f"FAIL | - | - |")
            continue
        r = rec["roofline"]
        mem = rec.get("memory", {})
        tot = sum(v for k, v in mem.items()
                  if k != "code_bytes" and isinstance(v, (int, float)))
        fit = "yes" if tot and tot < 16e9 else f"NO ({tot/1e9:.0f}GB)" if tot else "?"
        ratio = rec.get("useful_flops_ratio")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['bottleneck']} | "
            f"{ratio:.2f} | {fit} |" if ratio is not None else
            f"| {rec['arch']} | {rec['shape']} | - | - | - | ? | - | - |")
    return "\n".join(rows)


def dryrun_summary() -> dict:
    summary = {}
    for mesh in ("16x16", "2x16x16"):
        recs = load(mesh)
        summary[mesh] = {
            "cells": len(recs),
            "compiled_ok": sum(1 for r in recs if r.get("ok")),
            "skipped_documented": sum(1 for r in recs if r.get("skipped")),
            "failed": sum(1 for r in recs if r.get("ok") is False),
        }
    return summary


def run(quick: bool = True) -> dict:
    return {"summary": dryrun_summary(),
            "table_single_pod": roofline_table("16x16"),
            "table_multi_pod": roofline_table("2x16x16")}


if __name__ == "__main__":
    res = run()
    print(json.dumps(res["summary"], indent=1))
    print("\n== single-pod (16x16) ==\n" + res["table_single_pod"])
