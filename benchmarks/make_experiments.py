"""Generate EXPERIMENTS.md (§Dry-run, §Roofline, §Perf) from the dry-run
result JSONs + bench results.  The §Perf narrative (hypotheses and
conclusions) lives in PERF_LOG below, with numbers pulled live from the
tagged result files so the document can never drift from the data."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results" / "dryrun"


def cell(base: str, tag: str = "") -> dict:
    f = RESULTS / f"{base}{'__' + tag if tag else ''}.json"
    if not f.exists():
        return {}
    return json.loads(f.read_text())


def row(base: str, tag: str, label: str) -> str:
    d = cell(base, tag)
    if not d or not d.get("ok"):
        return f"| {label} | - | - | - | - | - | (missing/failed) |"
    r = d["roofline"]
    # ladder comparability: use the uncorrected collective term (older
    # ladder entries predate the f32-promotion correction)
    coll = r.get("collective_uncorrected_s", r["collective_s"])
    m = d.get("memory", {})
    tot = sum(v for k, v in m.items()
              if k != "code_bytes" and isinstance(v, (int, float))) / 1e9
    return (f"| {label} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{coll:.3f} | {max(r['compute_s'],r['memory_s'],coll):.3f} "
            f"| {d['useful_flops_ratio']:.2f} | {tot:.1f} GB |")


HDR = ("| variant | compute s | memory s | collective s | dominant s | "
       "useful/HLO | mem/device |\n|---|---|---|---|---|---|---|")


# (cell base, [(tag, label, hypothesis, verdict)])
PERF_LOG = [
    ("qwen2.5-32b__train_4k__16x16",
     "Cell A — qwen2.5-32b x train_4k (most representative: the flagship "
     "dense-train workload the framework's pmake campaigns schedule). "
     "Baseline bottleneck: memory.",
     [("orig", "A0 baseline (remat=full, mb=4, zero1)", "", ""),
      ("p1probs", "A1 probs-bf16 (cast after softmax)",
       "halve fp32 prob-buffer traffic",
       "REFUTED: extra convert buffers made traffic WORSE (31.7->36.2s); "
       "lesson: casting after materialization adds buffers — the dtype must "
       "change at the producing op"),
      ("p5staticskip", "A2 static causal skip (unrolled q-blocks)",
       "lax.cond skipping is invisible statically AND costs full wall-time "
       "slots; restructuring to scan only j<=i blocks halves score blocks",
       "CONFIRMED: memory 31.7->17.4s (-45%), flops -3%"),
      ("p6bf16ops", "A3 + bf16 einsum operands w/ fp32 accumulation",
       "explicit f32 upcasts in flash materialize f32 Q/K copies and make "
       "backward all-reduces fp32; bf16 operands + "
       "preferred_element_type=f32 match MXU semantics exactly",
       "REFUTED on this host: the CPU backend promotes bf16 dots to f32 "
       "anyway, so neither memory nor collectives moved — this experiment "
       "EXPOSED the f32-promotion artifact, now corrected in the "
       "methodology (collective term reports bf16-corrected width)"),
      ("p7gradcast", "A4 + grad_cast cotangent guards",
       "pin backward all-reduce dtype to bf16 at projection boundaries",
       "NEUTRAL here (masked by the same CPU artifact), kept: correct and "
       "required on real TPUs"),
      ("opt", "A* final (static skip, corrected accounting)", "", ""),
      ]),
    ("deepseek-v2-lite-16b__train_4k__16x16",
     "Cell B — deepseek-v2-lite x train_4k (most collective-bound baseline: "
     "MoE dispatch + TP all-reduces).",
     [("orig", "B0 baseline", "", ""),
      ("p1probskip", "B1 probs-bf16 + cond-skip",
       "same as A1 via lax.cond", "REFUTED (same lesson as A1)"),
      ("p2staticskip", "B2 static causal skip",
       "as A2", "CONFIRMED: memory 7.4->5.9s (-20%)"),
      ("p3bf16ops", "B3 + bf16 einsum operands", "as A3",
       "as A3 (CPU f32-promotion artifact)"),
      ("opt", "B* final (static skip, corrected accounting)", "", ""),
      ]),
    ("arctic-480b__decode_32k__16x16",
     "Cell C — arctic-480b x decode_32k (worst roofline fraction 0.005; "
     "also does NOT fit: 117 GB/device of expert weights replicated over "
     "the data axis).",
     [("orig", "C0 baseline (1D sharding)", "", ""),
      ("p1shard2d", "C1 2D expert-weight sharding",
       "spreading expert weights over data axis fixes fit and divides "
       "weight reads by 16",
       "PARTIAL: fit 132->23 GB, but GSPMD all-gathered the 2D weights "
       "each layer (collective 0.06->2.45s) — naive 2D sharding moves "
       "weights to tokens"),
      ("p3moeff", "C2 + moe_ff output hints",
       "pin expert-FFN activations to the weight shard layout so matmuls "
       "stay local", "CONFIRMED: collective 2.45->0.19s"),
      ("p4bf16attn", "C3 + no-fp32-cache-copy decode attention",
       "einsum on cache dtype w/ fp32 accumulation removes per-layer f32 "
       "cache copies", "CONFIRMED: memory 0.35->0.15s"),
      ("p5psum", "C4 + contraction-dim dispatch hints",
       "slicing the (replicated) dispatch on the contraction dim turns "
       "weight movement into a tiny psum of outputs",
       "CONFIRMED: collective 0.19->0.055s, memory 0.15->0.10s; "
       "net 5.6x vs baseline and fits at 512 chips"),
      ("p6parambf16", "C5 + bf16 params",
       "halve weight bytes",
       "REFUTED under the traffic model: f32 dispatch forces full f32 "
       "weight converts (temp 6.3->15.3 GB); keep fp32 params + bf16 "
       "compute"),
      ("opt", "C* final (=C4 config, corrected accounting)", "", ""),
      ]),
]


def perf_section() -> str:
    out = []
    for base, intro, entries in PERF_LOG:
        out.append(f"\n### {base.replace('__', ' / ')}\n\n{intro}\n")
        out.append(HDR)
        for tag, label, _, _ in entries:
            out.append(row(base, tag, label))
        out.append("\nIteration log (hypothesis -> change -> result):\n")
        for tag, label, hyp, verdict in entries:
            if not hyp:
                continue
            out.append(f"- **{label}** — *hypothesis:* {hyp}. "
                       f"*Result:* {verdict}.")
    return "\n".join(out)


def main():
    from benchmarks.roofline import dryrun_summary, roofline_table
    bench = {}
    bj = ROOT / "benchmarks" / "results" / "bench_results.json"
    if bj.exists():
        bench = json.loads(bj.read_text())
    summary = dryrun_summary()
    checks = bench.get("metg", {}).get("checks", {})
    million = bench.get("million_tasks", {})

    doc = f"""# EXPERIMENTS

Reproduction of *Three Practical Workflow Schedulers for Easy Maximum
Parallelism* (Rogers, 2021) as a multi-pod JAX framework, plus the
beyond-paper roofline/perf program.  All numbers regenerate via
`PYTHONPATH=src python -m benchmarks.make_experiments`.

## §Paper-validation (the faithful-reproduction baseline)

Scaling-law reproduction against the paper's own measurements
(`benchmarks/metg.py`, `tests/test_metg.py`):

| claim (paper §4/§5/§6) | paper | this repo |
|---|---|---|
| METG ordering at 864 ranks | mpi-list < dwork < pmake | {checks.get('ordering_mpilist<dwork<pmake', '?')} |
| dwork METG at 864 ranks | ~25 ms | {checks.get('paper_864_dwork_ms', '?')} ms (rtt x ranks) |
| pmake METG at 864 ranks | ~4.5 s | {checks.get('paper_864_pmake_s', '?')} s (jsrun log-fit + alloc) |
| dwork METG scales linearly with ranks | yes | {checks.get('dwork_scales_linearly', '?')} |
| per-task server latency | 23 us (ZeroMQ/Summit) | {checks.get('measured_dwork_rtt_us', '?')} us in-proc / {checks.get('measured_tcp_rtt_us', '?')} us TCP (this container) |
| 1M tasks created+dequeued | "about a minute" | {million.get('extrapolated_1M_s', '?')} s extrapolated ({million.get('tasks_per_s', '?')} tasks/s) |

Fig. 4 / Fig. 5 / Table 1 / Table 4 reproductions: `benchmarks/run.py`
(metg, overhead, comparison harnesses); Fig. 1 campaign and Fig. 3
histogram: `examples/train_campaign.py`, `examples/analytics_mpilist.py`.

## §Dry-run

`src/repro/launch/dryrun.py` lowers + compiles every
(architecture x shape x mesh) cell with 512 placeholder host devices;
per-cell JSON in `benchmarks/results/dryrun/`.

| mesh | cells | compiled ok | documented skips | failed |
|---|---|---|---|---|
| 16x16 (single pod, 256 chips) | {summary['16x16']['cells']} | {summary['16x16']['compiled_ok']} | {summary['16x16']['skipped_documented']} | {summary['16x16']['failed']} |
| 2x16x16 (two pods, 512 chips) | {summary['2x16x16']['cells']} | {summary['2x16x16']['compiled_ok']} | {summary['2x16x16']['skipped_documented']} | {summary['2x16x16']['failed']} |

Skips are the `long_500k` cells for pure full-attention architectures
(DESIGN.md §6); every cell that the assignment defines as runnable
compiles on both meshes.  Sharding configuration: DP over (pod, data),
TP/EP over model, ZeRO-1 optimizer sharding over data, sequence-sharded
KV caches (flash-decoding), train cells remat=full + 4 microbatches.

## §Roofline (single-pod, per device; TPU v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s/link)

Methodology: XLA `cost_analysis()` counts while-loop bodies ONCE (verified:
a scan of 8 matmuls reports 1), so terms are derived from a custom pass
over the SPMD-partitioned HLO (`launch/hlo_analysis.py`): call-graph walk
with `known_trip_count` multipliers; flops = dot products (2*out*contract);
memory = 2x materialized-buffer bytes with slice-aware DUS accounting;
collectives = result bytes by kind.  All per-device.  `useful/HLO` =
6*N_active*D (train) or 2*N_active*D (serve) over counted flops.

{{ROOFLINE_TABLE}}

Baseline observations:
- nearly every cell is **memory-term dominated** on this traffic model;
  the largest contributor in attention-bearing train cells is the blockwise
  softmax's materialized probability buffers — exactly the buffers the
  validated Pallas flash kernel (`kernels/flash_attention/`) keeps in VMEM.
  The §Perf program therefore attacks materialization counts and dtype
  width rather than raw flops.
- `useful/HLO` is 0.6-0.9 for dense train cells (remat recompute accounts
  for ~8/6 of model flops; attention+vocab the rest); whisper/gemma are
  vocab-dominated (0.27/0.34); rwkv6 reaches 0.88-0.90 (matmul-rich
  chunked WKV).
- fit: train cells of the >=30B models exceed 16 GB/device on a single pod
  at mb=4 (expected — these models train on more chips); the multi-pod
  mesh halves per-device state.  arctic decode fit is addressed in §Perf.

## §Perf — hillclimbing the three selected cells
{{PERF}}

### Paper-faithful baseline vs beyond-paper optimized (summary)

The paper's contribution (the schedulers) is orthogonal to kernel-level
perf, so the "paper-faithful" configuration is the baseline sharding with
no beyond-paper tricks; the optimized rows add: static causal skip, MXU
dtype discipline (bf16 operands/fp32 accumulation), flash-decoding cache
layout, 2D expert-weight serving shards, and contraction-dim dispatch.

| cell | baseline dominant | optimized (raw) | optimized (bf16-corrected collectives) | gain raw/corrected |
|---|---|---|---|---|
{{SUMMARY_ROWS}}

(The "corrected" column counts reduction collectives at bf16 width — the
TPU value; the CPU host promotes bf16 dots to f32, inflating reduce bytes
2x in the raw HLO.  Baselines predate the corrected field and are raw.)

Stop criterion: three consecutive <5% iterations was reached on cell C
(C4->C5 regressed, reverted); cells A/B stopped at the documented best.
"""
    from benchmarks.roofline import roofline_table
    doc = doc.replace("{ROOFLINE_TABLE}", roofline_table("16x16"))
    doc = doc.replace("{PERF}", perf_section())

    def dom(d):
        r = d["roofline"]
        return max(r["compute_s"], r["memory_s"],
                   r.get("collective_uncorrected_s", r["collective_s"]))

    def best(base, tags):
        ds = [cell(base, t) for t in tags]
        ds = [d for d in ds if d and d.get("ok")]
        return min(ds, key=dom)

    rows = []
    for base, _, entries in PERF_LOG:
        b = cell(base, "orig") or cell(base)
        o = cell(base, "opt") or best(base, [t for t, *_ in entries if t])
        if not b or not o:
            continue
        bd, od = dom(b), dom(o)
        # corrected bound: bf16-width collectives (the TPU value)
        oc = max(o["roofline"]["compute_s"], o["roofline"]["memory_s"],
                 o["roofline"]["collective_s"])
        rows.append(f"| {base.replace('__', ' / ')} | {bd:.3f} s "
                    f"({b['roofline']['bottleneck']}) | {od:.3f} s | "
                    f"{oc:.3f} s ({o['roofline']['bottleneck']}) "
                    f"| {bd/od:.2f}x / {bd/oc:.2f}x |")
    doc = doc.replace("{SUMMARY_ROWS}", "\n".join(rows))
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote {ROOT / 'EXPERIMENTS.md'} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
