"""Data-plane benchmark: peer-to-peer dependency fetches vs hauling
every result through the hub, emitted as BENCH_xfer.json — the CI gate
for the worker-to-worker data plane.

One seeded workload, run twice on the proc transport:

  * **hub mode**  — `inline_bytes` is set above every payload, so each
    producer uploads its result inline with `CompleteSteal` and every
    consumer pulls it back down from the hub (two copies per value
    through the single front door, the pre-data-plane behavior).
  * **peer mode** — `inline_bytes` is small, so producers advertise a
    location instead, and consumers dial the producing worker's data
    listener directly (one copy, off the hub).

The workload is transfer-bound by construction: producers emit
multi-hundred-KiB values, consumers fan them in from other workers
(producers are awaited before consumers are submitted, so values are
spread across the pool before anyone fetches).  Sink values are
digest-checked against a local model, so both modes also re-prove the
zero-loss contract end to end.

Gate (`--check`) asserts, with the usual 3-attempt / machine-scaled
rhythm of the other benchmark gates:

  * exact sink values in BOTH modes (zero loss);
  * peer mode really used the peer path (fetch count floor) and moved
    the payload traffic OFF the hub (hub-path bytes a small fraction of
    hub mode's);
  * peer mode is not slower than hub mode (ratio bound — machine speed
    cancels in the ratio) and the whole run stays within a loose
    machine-scaled multiple of the committed baseline wall clock.

Modes:
    (default)   run -> BENCH_xfer.json (+ stdout)
    --check     re-run and compare against the committed baseline
"""
from __future__ import annotations

import hashlib
import json
import sys
import time
from pathlib import Path

from repro.client import Client

REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "BENCH_xfer.json"

N_PRODUCERS = 32
N_CONSUMERS = 32
PAYLOAD = 384 * 1024           # producer value size: well above 64 KiB
WORKERS = 4
ATTEMPTS = 3                   # best-of, per mode
PEER_RATIO_LIMIT = 1.25        # peer wall must stay within this x hub wall
HUB_BYTES_FRACTION = 0.25      # peer mode's hub-path payload budget
CHECK_WALL_TOLERANCE = 4.0     # loose absolute bound vs baseline


def _calibrate_us() -> float:
    """Machine-speed probe (same estimator as the other benchmark gates)."""
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        total = 0
        for i in range(100000):
            total += i * i
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _expected() -> list:
    """Local model of the DAG: producer i's value, and each consumer's
    digest over its two fan-in dependencies."""
    prods = [(hashlib.sha256(f"xfer{i}".encode()).digest()
              * (PAYLOAD // 32 + 1))[:PAYLOAD] for i in range(N_PRODUCERS)]
    sinks = []
    for j in range(N_CONSUMERS):
        a = prods[j % N_PRODUCERS]
        b = prods[(j * 7 + 3) % N_PRODUCERS]
        sinks.append(hashlib.md5(a + b).hexdigest())
    return sinks


def _run_mode(mode: str) -> dict:
    """One full DAG on the proc transport.  hub: payloads ride inline
    through the front door; peer: locations only, consumers dial the
    producing worker directly."""
    inline = (64 * 1024 * 1024) if mode == "hub" else 4096

    def make_producer(i):
        def fn():
            return (hashlib.sha256(f"xfer{i}".encode()).digest()
                    * (PAYLOAD // 32 + 1))[:PAYLOAD]
        return fn

    def make_consumer():
        def fn(a, b):
            return hashlib.md5(a + b).hexdigest()
        return fn

    t0 = time.perf_counter()
    with Client(transport="proc", workers=WORKERS, steal_n=2,
                heartbeat_s=0.2, inline_bytes=inline) as c:
        prods = [c.submit(make_producer(i), key=f"xp{i}")
                 for i in range(N_PRODUCERS)]
        # let every producer finish (values spread across the pool)
        # WITHOUT materializing them client-side — f.done() polls, so
        # the only payload motion measured is worker-to-worker
        c._ensure_running()
        deadline = time.monotonic() + 60
        while not all(f.done() for f in prods):
            if time.monotonic() > deadline:
                raise AssertionError(f"[{mode}] producers never finished")
            time.sleep(0.002)
        sinks = [c.submit(make_consumer(), prods[j % N_PRODUCERS],
                          prods[(j * 7 + 3) % N_PRODUCERS], key=f"xc{j}")
                 for j in range(N_CONSUMERS)]
        values = c.gather(sinks, timeout=120)
        with c.engine._xfer_lock:
            by_path = {p: list(v) for p, v in c.engine.xfer_totals.items()}
        lost = c.engine.xfer_lost_total
    wall = time.perf_counter() - t0
    if values != _expected():
        raise AssertionError(f"[{mode}] sink digests corrupted — the data "
                             "plane delivered wrong dependency bytes")
    return {
        "wall_s": round(wall, 4),
        "xfer_by_path": {p: {"n": n, "bytes": b, "total_s": round(t, 4)}
                         for p, (n, b, t) in sorted(by_path.items())},
        "lost": lost,
    }


def run() -> dict:
    best: dict = {}
    for mode in ("hub", "peer"):
        for _ in range(ATTEMPTS):
            meas = _run_mode(mode)
            if mode not in best or meas["wall_s"] < best[mode]["wall_s"]:
                best[mode] = meas
    peer = best["peer"]["xfer_by_path"].get("peer", {})
    hub_bytes_peer = best["peer"]["xfer_by_path"].get(
        "hub", {}).get("bytes", 0)
    out = {
        "n_producers": N_PRODUCERS, "n_consumers": N_CONSUMERS,
        "payload_bytes": PAYLOAD, "workers": WORKERS,
        "hub": best["hub"], "peer": best["peer"],
        "peer_fetches": peer.get("n", 0),
        "peer_bytes": peer.get("bytes", 0),
        "peer_mode_hub_bytes": hub_bytes_peer,
        "peer_vs_hub_wall": round(
            best["peer"]["wall_s"] / max(best["hub"]["wall_s"], 1e-9), 3),
        "wall_s": round(best["hub"]["wall_s"] + best["peer"]["wall_s"], 4),
        "calibration_us": round(_calibrate_us(), 1),
    }
    _assert_invariants(out)
    return out


def _assert_invariants(meas: dict):
    """Mode-shape invariants: true on every machine, every run.  (Hub
    mode shows NO fetches at all — its payloads ride inline through the
    hub inside completions and task metadata, which is exactly the haul
    the peer path removes; its cost shows up in the wall-clock ratio.)"""
    if meas["hub"]["lost"] or meas["peer"]["lost"]:
        raise AssertionError(f"value loss without any injected fault: "
                             f"hub={meas['hub']['lost']} "
                             f"peer={meas['peer']['lost']}")
    floor = N_CONSUMERS // 4
    if meas["peer_fetches"] < floor:
        raise AssertionError(
            f"peer mode barely used the peer path: {meas['peer_fetches']} "
            f"fetches < floor {floor}")
    budget = meas["peer_bytes"] * HUB_BYTES_FRACTION
    if meas["peer_mode_hub_bytes"] > budget:
        raise AssertionError(
            f"peer mode still hauled {meas['peer_mode_hub_bytes']}B of "
            f"payload through the hub (> {HUB_BYTES_FRACTION:.0%} of its "
            f"{meas['peer_bytes']}B peer traffic)")


def run_check() -> int:
    """CI gate: the data plane must move payload traffic off the hub and
    stay at least as fast, on seeded DAGs with exact-value checks."""
    baseline = json.loads(BASELINE.read_text())
    scale = 1.0
    base_cal = baseline.get("calibration_us")
    if base_cal:
        scale = min(max(_calibrate_us() / base_cal, 1.0), 4.0)
    wall_limit = baseline["wall_s"] * CHECK_WALL_TOLERANCE * scale
    print(f"machine-speed scale vs baseline: {scale:.2f}x "
          f"(wall limit {wall_limit:.1f}s)")
    last_err = None
    for attempt in range(3):
        try:
            meas = run()
        except AssertionError as e:
            last_err = e
            print(f"attempt {attempt + 1}: INVARIANT FAILED: {e}",
                  file=sys.stderr)
            time.sleep(2)
            continue
        ratio = meas["peer_vs_hub_wall"]
        ok_ratio = ratio <= PEER_RATIO_LIMIT   # machine speed cancels here
        ok_wall = meas["wall_s"] <= wall_limit
        print(f"xfer: peer {meas['peer']['wall_s']:.2f}s vs hub "
              f"{meas['hub']['wall_s']:.2f}s (ratio {ratio:.2f}, "
              f"limit {PEER_RATIO_LIMIT}) "
              f"peer_fetches={meas['peer_fetches']} "
              f"peer_mode_hub_bytes={meas['peer_mode_hub_bytes']} "
              f"wall={meas['wall_s']:.2f}s (limit {wall_limit:.1f}s) "
              f"{'OK' if ok_ratio and ok_wall else 'FAILED'}")
        if ok_ratio and ok_wall:
            return 0
        last_err = AssertionError(
            f"ratio {ratio} > {PEER_RATIO_LIMIT}" if not ok_ratio
            else f"wall {meas['wall_s']} > {wall_limit}")
        time.sleep(2)
    print(f"xfer gate failed: {last_err}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    if "--check" in sys.argv:
        sys.exit(run_check())
    result = run()
    BASELINE.write_text(json.dumps(result, indent=1))
    print(json.dumps(result, indent=1))
    print(f"\nwrote {BASELINE}", file=sys.stderr)
