"""Engine overhead benchmark: tasks/sec + per-task overhead for each
scheduler adapter at 1 / 4 / 16 workers, emitted as BENCH_engine.json.

Seeds the repo's perf trajectory: every future scaling PR (forwarding
trees, async serving, multi-backend) should move these numbers, and the
empirical-vs-analytic METG crosscheck keeps the `core/metg.py` laws
honest against the running code.
"""
from __future__ import annotations

import json
import sys
import tempfile
import time
from pathlib import Path

from repro.core.dwork import Client, InProcTransport, TaskServer, run_pool
from repro.core.engine import crosscheck
from repro.core.metg import METGModel, PAPER_DWORK_RTT
from repro.core.mpi_list import Context
from repro.core.pmake import PMake

WORKER_COUNTS = (1, 4, 16)


def bench_dwork(n_tasks: int, workers: int, steal_n: int = 4) -> dict:
    srv = TaskServer()
    boss = Client(InProcTransport(srv), "boss")
    for i in range(n_tasks):
        boss.create(f"t{i}", meta={"x": i})
    rep = run_pool(srv, lambda name, meta: (True, meta["x"] * 2),
                   workers=workers, steal_n=steal_n)
    ov = rep.overhead()
    model = METGModel.from_measured(rtt_s=ov.rpc_per_task_s)
    # rpc_per_task_s is already amortized over the Steal-n batch, so the
    # analytic law is evaluated at steal_n=1 (no double-counting)
    return {
        **ov.summary(),
        "crosscheck": crosscheck("dwork", ov.per_task_overhead_s,
                                 model.dwork_metg(workers)),
        "rtt_vs_paper": crosscheck("dwork-rtt", ov.rpc_per_task_s,
                                   PAPER_DWORK_RTT, factor=30.0),
    }


def bench_pmake(n_tasks: int, workers: int) -> dict:
    rules = ('w:\n  resources: {time: 1, nrs: 1}\n'
             '  out: {o: "w_{n}.out"}\n  script: "echo {n}"\n')
    targets = (f'all:\n  dirname: .\n  loop:\n    n: "range({n_tasks})"\n'
               '  tgt: {o: "w_{n}.out"}\n')
    pm = PMake(rules, targets, root=tempfile.mkdtemp(),
               total_nodes=workers, transport="inproc",
               runner=lambda t: True)
    stats = pm.run()
    ov = pm.report.overhead()
    model = METGModel.from_measured(launch_s=ov.rpc_per_task_s)
    return {
        **ov.summary(),
        "done": stats["done"],
        "crosscheck": crosscheck("pmake", ov.per_task_overhead_s,
                                 model.pmake_metg(workers)),
    }


def bench_mpilist(n_items: int, workers: int, ranks: int = 16,
                  sigma: float = 1e-3) -> dict:
    C = Context(ranks, engine_workers=workers, straggler_sigma=sigma,
                seed=0)
    t0 = time.perf_counter()
    steps = max(1, n_items // 1000)
    for _ in range(steps):
        C.scatter(list(range(1000))).map(lambda x: x * 2)
    wall = time.perf_counter() - t0
    n_rank_tasks = steps * ranks
    return {
        "ranks": ranks, "supersteps": steps,
        "rank_tasks_per_s": round(n_rank_tasks / wall, 1),
        "mean_sync_gap_ms": round(1e3 * sum(C.gaps) / len(C.gaps), 4),
        "crosscheck": C.straggler_crosscheck(),
    }


def run(quick: bool = True) -> dict:
    n = 300 if quick else 2000
    out = {"n_tasks": n, "schedulers": {}}
    for name, fn in (("dwork", bench_dwork), ("pmake", bench_pmake),
                     ("mpi-list", bench_mpilist)):
        out["schedulers"][name] = {
            f"workers={w}": fn(n, w) for w in WORKER_COUNTS}
    return out


if __name__ == "__main__":
    quick = "--full" not in sys.argv
    result = run(quick=quick)
    path = Path(__file__).resolve().parents[1] / "BENCH_engine.json"
    path.write_text(json.dumps(result, indent=1, default=str))
    print(json.dumps(result, indent=1, default=str))
    print(f"\nwrote {path}", file=sys.stderr)
