"""Engine overhead benchmark: tasks/sec + per-task overhead for each
scheduler adapter at 1 / 4 / 16 workers, emitted as BENCH_engine.json.

Seeds the repo's perf trajectory: every future scaling PR (forwarding
trees, async serving, multi-backend) should move these numbers, and the
empirical-vs-analytic METG crosscheck keeps the `core/metg.py` laws
honest against the running code.

Every multi-worker cell reports `parallel_speedup` (tasks/s at N
workers / tasks/s at 1): the in-process transports sit near 1.0x on
CPU-bound work (the GIL serializes compute), and the `proc_cpu` section
is where real speedup appears — worker processes over the comm layer,
measured steady-state (pool spawned and handshaken before the clock
starts) with an injected SIGKILL cell proving zero task loss.

Modes:
    (default)   quick run -> BENCH_engine.json (+ stdout)
    --full      2000 tasks instead of 300
    --sweep     steal_n x shards x transport sweep -> BENCH_engine_sweep.json
    --check     quick dwork run compared against the committed
                BENCH_engine.json; exits non-zero if per-task overhead
                regressed > CHECK_TOLERANCE, or if the CPU-bound proc
                speedup cell loses GIL escape (the CI perf gate)
"""
from __future__ import annotations

import gc
import json
import os
import signal
import sys
import tempfile
import time
from pathlib import Path

from repro.core.dwork import Client, InProcTransport, TaskServer, run_pool
from repro.core.engine import Engine, crosscheck
from repro.core.metg import METGModel, PAPER_DWORK_RTT
from repro.core.mpi_list import Context
from repro.core.pmake import PMake

WORKER_COUNTS = (1, 4, 16)
CHECK_TOLERANCE = 1.25          # CI fails if overhead grows > 25%
INSTR_TOLERANCE = 1.05          # metrics on vs off: <= 5% growth budget
# ratio gates are meaningless at the noise floor: a sub-microsecond
# jitter on a ~10us overhead reads as "percent growth" — the absolute
# floor below absorbs it (scaled by machine speed in run_check)
INSTR_FLOOR_US = 0.3
REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "BENCH_engine.json"
SWEEP_OUT = REPO_ROOT / "BENCH_engine_sweep.json"
# the GIL-escape gate: CPU-bound tasks at 4 proc workers must beat the
# 1-worker rate by this factor — scaled down when the machine itself
# cannot parallelize (the gate tests OUR dispatch, not the host's cores)
SPEEDUP_MIN_4CORE = 2.0
SPEEDUP_MIN_2CORE = 1.2


def _dwork_once(n_tasks: int, workers: int, steal_n: int,
                shards: int, transport: str):
    if shards > 1:
        from repro.core.dwork.sharded import ShardedHub
        srv = ShardedHub(shards)
        for i in range(n_tasks):
            srv.create(f"t{i}", meta={"x": i})
    else:
        srv = TaskServer()
        boss = Client(InProcTransport(srv), "boss")
        for i in range(n_tasks):
            boss.create(f"t{i}", meta={"x": i})
    return run_pool(srv, lambda name, meta: (True, meta["x"] * 2),
                    workers=workers, steal_n=steal_n, transport=transport)


def bench_dwork(n_tasks: int, workers: int, steal_n: int = 4,
                shards: int = 1, transport: str = "inproc",
                repeats: int = 3) -> dict:
    # best-of-N: scheduler/GC hiccups only ever ADD time, so the minimum
    # is the stable estimate of per-task cost — and both the committed
    # baseline and the CI --check gate use the same estimator, which
    # keeps the 25% regression tolerance meaningful
    best = None
    for _ in range(max(repeats, 1)):
        gc.collect()
        rep_i = _dwork_once(n_tasks, workers, steal_n, shards, transport)
        ov_i = rep_i.overhead()
        if best is None or ov_i.per_task_overhead_s < best[1].per_task_overhead_s:
            best = (rep_i, ov_i)
    rep, ov = best
    # forwarding-tree / sharded-apex hop attribution (op="hop:L<k>" and
    # "hop:L<k>:s<j>"): per-hop mean latency so the sweep can show WHERE
    # tree time accrues; empty for transports with no hops
    rpc_hops = {op: {"n": c, "mean_us": round(tot / c * 1e6, 2)}
                for op, (c, tot) in sorted(ov.rpc_by_op.items())
                if op.startswith("hop:")}
    model = METGModel.from_measured(rtt_s=ov.rpc_per_task_s)
    # rpc_per_task_s is already amortized over the Steal-n batch, so the
    # analytic law is evaluated at steal_n=1 (no double-counting).  The
    # law's P is the number of CONCURRENT clients hammering the server,
    # which for the serial inline transports is ov.workers == 1, not the
    # configured pool size — evaluating at the pool size would predict a
    # 16x dispatch bound that a serial dispatch loop never exhibits.
    # The reported "workers" field IS the configured pool size
    # (rep.pool_workers).
    return {
        **ov.summary(),
        "workers": rep.pool_workers,
        **({"rpc_hops": rpc_hops} if rpc_hops else {}),
        "crosscheck": crosscheck("dwork", ov.per_task_overhead_s,
                                 model.dwork_metg(ov.workers,
                                                  shards=shards)),
        "rtt_vs_paper": crosscheck("dwork-rtt", ov.rpc_per_task_s,
                                   PAPER_DWORK_RTT, factor=30.0),
    }


def bench_pmake(n_tasks: int, workers: int) -> dict:
    rules = ('w:\n  resources: {time: 1, nrs: 1}\n'
             '  out: {o: "w_{n}.out"}\n  script: "echo {n}"\n')
    targets = (f'all:\n  dirname: .\n  loop:\n    n: "range({n_tasks})"\n'
               '  tgt: {o: "w_{n}.out"}\n')
    pm = PMake(rules, targets, root=tempfile.mkdtemp(),
               total_nodes=workers, transport="inproc",
               runner=lambda t: True)
    stats = pm.run()
    ov = pm.report.overhead()
    model = METGModel.from_measured(launch_s=ov.rpc_per_task_s)
    return {
        **ov.summary(),
        "workers": pm.report.pool_workers,
        "done": stats["done"],
        "crosscheck": crosscheck("pmake", ov.per_task_overhead_s,
                                 model.pmake_metg(workers)),
    }


def bench_mpilist(n_items: int, workers: int, ranks: int = 16,
                  sigma: float = 1e-3) -> dict:
    C = Context(ranks, engine_workers=workers, straggler_sigma=sigma,
                seed=0)
    t0 = time.perf_counter()
    steps = max(1, n_items // 1000)
    for _ in range(steps):
        C.scatter(list(range(1000))).map(lambda x: x * 2)
    wall = time.perf_counter() - t0
    n_rank_tasks = steps * ranks
    return {
        "ranks": ranks, "supersteps": steps,
        "rank_tasks_per_s": round(n_rank_tasks / wall, 1),
        "mean_sync_gap_ms": round(1e3 * sum(C.gaps) / len(C.gaps), 4),
        "crosscheck": C.straggler_crosscheck(),
    }


def _spin_for(target_s: float) -> int:
    """Calibrate a pure-Python spin count that burns ~target_s of CPU on
    THIS machine, so the proc cells measure the same wall-clock shape on
    fast and slow hosts alike."""
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        sum(i * i for i in range(100000))
        best = min(best, time.perf_counter() - t0)
    return max(int(100000 * target_s / best), 1000)


def _proc_cpu_once(n_tasks: int, workers: int, spin: int,
                   kill_after_s: float = 0.0) -> dict:
    """One steady-state CPU-bound run over `transport="proc"`: spawn the
    pool, wait for every Hello handshake, THEN start the clock — the
    tasks/s number is dispatch + compute, not process startup.  With
    `kill_after_s` > 0 one worker process takes a SIGKILL mid-run (the
    zero-loss acceptance drill: its in-flight work must requeue).

    The executor is a lambda (cloudpickle ships it by value in the
    handshake) spinning `meta["spin"]` iterations — pure-Python compute,
    exactly what the GIL serializes for in-process transports."""
    from repro.core.engine import Engine
    eng = Engine(transport="proc", workers=workers, resident=True,
                 heartbeat_s=0.2)
    eng.start(lambda name, meta: (True, sum(
        i * i for i in range(meta["spin"]))))
    if not eng.wait_workers(workers, timeout=60):
        eng.shutdown()
        raise RuntimeError(f"proc pool of {workers} never handshook")
    t0 = time.perf_counter()
    for i in range(n_tasks):
        eng.submit(f"c{i}", meta={"spin": spin})
    killed = 0
    if kill_after_s > 0:
        time.sleep(kill_after_s)
        victim = next(iter(eng.worker_pids().values()), None)
        if victim:
            os.kill(victim, signal.SIGKILL)
            killed = 1
    drained = eng.drain(timeout=300)
    wall = time.perf_counter() - t0
    rep = eng.shutdown()
    done_ok = sum(1 for r in rep.results.values() if r.ok)
    return {
        "workers": workers, "n_tasks": n_tasks, "spin": spin,
        "wall_s": round(wall, 4),
        "tasks_per_s": round(n_tasks / wall, 1),
        "done_ok": done_ok, "lost": n_tasks - done_ok,
        "killed": killed, "worker_deaths": eng.worker_deaths,
        "drained": bool(drained),
    }


def bench_proc_cpu(n_tasks: int = 96, task_s: float = 0.008,
                   repeats: int = 2) -> dict:
    """The GIL-escape section: CPU-bound tasks/s at 1 vs 4 proc workers
    (`parallel_speedup` = rate at 4 / rate at 1), plus the SIGKILL cell:
    the same workload with one worker process killed mid-run — `lost`
    must be 0 (in-flight work requeues onto the survivors)."""
    spin = _spin_for(task_s)
    cells = {}
    for w in (1, 4):
        best = None
        for _ in range(max(repeats, 1)):
            gc.collect()
            r = _proc_cpu_once(n_tasks, w, spin)
            if best is None or r["tasks_per_s"] > best["tasks_per_s"]:
                best = r
        cells[f"workers={w}"] = best
    speedup = (cells["workers=4"]["tasks_per_s"]
               / cells["workers=1"]["tasks_per_s"])
    cells["workers=4"]["parallel_speedup"] = round(speedup, 3)
    # the kill cell runs slower tasks (4x spin) so the SIGKILL reliably
    # lands mid-flight even on a fast machine
    kill = _proc_cpu_once(n_tasks, 4, spin * 4,
                          kill_after_s=task_s * 4 * n_tasks / 4 * 0.3)
    # cpu_count contextualizes the speedup: 4 worker processes on a
    # 1-core host honestly report ~1.0x — the dispatch scales, the
    # silicon doesn't (the --check gate scales its bar the same way)
    return {"task_target_ms": round(task_s * 1e3, 2),
            "cpu_count": os.cpu_count() or 1,
            "parallel_speedup": round(speedup, 3),
            "cells": cells, "sigkill": kill}


def _engine_once(n_tasks: int, instrumented: bool) -> float:
    """One batch Engine run (the executor hot loop, no shim layers);
    returns per-task overhead in seconds.  With `instrumented=True` a
    live MetricsRegistry is attached first — callback instruments over
    the loop's own tables plus the sampled rpc histograms — exactly what
    `Client.stats_server()` wires up."""
    eng = Engine(workers=4, steal_n=4)
    for i in range(n_tasks):
        eng.submit(f"t{i}", meta={"x": i})
    if instrumented:
        from repro.core.obs import instrument
        instrument(engine=eng)
    rep = eng.run(lambda name, meta: (True, meta["x"] * 2))
    return rep.overhead().per_task_overhead_s


def bench_instrumentation(n_tasks: int = 1000, repeats: int = 5) -> dict:
    """Instrumentation-overhead cell: per-task overhead with metrics
    attached vs the bare engine.  The two sides are interleaved (off,
    on, off, on, ...) and both take the best-of-N minimum, so machine
    drift during the measurement hits both equally."""
    best_off = best_on = float("inf")
    for _ in range(max(repeats, 1)):
        gc.collect()
        best_off = min(best_off, _engine_once(n_tasks, False))
        gc.collect()
        best_on = min(best_on, _engine_once(n_tasks, True))
    growth = (best_on / best_off) if best_off > 0 else 1.0
    return {
        "n_tasks": n_tasks,
        "off_us": round(best_off * 1e6, 2),
        "on_us": round(best_on * 1e6, 2),
        "growth": round(growth, 4),
    }


def _warmup():
    """One throwaway run so the measured runs see warm bytecode/caches
    (the first dispatch loop of a process is ~2x slower)."""
    bench_dwork(100, 1)
    gc.collect()


def _calibrate_us() -> float:
    """Machine-speed probe: a pure-Python spin loop, independent of the
    code under test.  Committed alongside the baseline so the --check
    gate can scale absolute microsecond limits when it runs on slower
    hardware (e.g. a shared CI runner) than the machine that produced
    the baseline."""
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        total = 0
        for i in range(100000):
            total += i * i
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _add_speedups(cells: dict) -> dict:
    """Annotate each multi-worker cell with `parallel_speedup` (its
    task rate over the workers=1 cell's) — near 1.0 for the in-process
    transports on these no-op tasks, the honest GIL-bound baseline the
    `proc_cpu` section is measured against."""
    rate_key = next((k for k in ("tasks_per_s", "rank_tasks_per_s")
                     if k in cells.get("workers=1", {})), None)
    base = cells["workers=1"][rate_key] if rate_key else 0
    if base:
        for label, cell in cells.items():
            cell["parallel_speedup"] = round(cell[rate_key] / base, 3)
    return cells


def run(quick: bool = True) -> dict:
    n = 300 if quick else 2000
    _warmup()
    out = {"n_tasks": n, "calibration_us": round(_calibrate_us(), 1),
           "schedulers": {}}
    for name, fn in (("dwork", bench_dwork), ("pmake", bench_pmake),
                     ("mpi-list", bench_mpilist)):
        out["schedulers"][name] = _add_speedups(
            {f"workers={w}": fn(n, w) for w in WORKER_COUNTS})
    out["proc_cpu"] = bench_proc_cpu()
    out["instrumentation"] = bench_instrumentation()
    return out


def run_sweep(quick: bool = True) -> dict:
    """steal_n x shards x transport sweep for the dwork adapter — the
    perf trajectory for the engine's three dispatch knobs, INCLUDING the
    composed tree x shards>1 cells (the sharded hub behind the
    forwarding tree: hash routing at the apex, per-shard hop
    attribution in `rpc_hops`)."""
    n = 300 if quick else 2000
    workers = 4
    _warmup()
    out = {"n_tasks": n, "workers": workers, "cells": []}
    for transport in ("inproc", "thread", "tree", "proc"):
        # proc spawns real processes per run: fewer repeats keeps the
        # sweep tractable without changing the best-of estimator
        reps = 2 if transport == "proc" else 3
        # per-transport 1-worker reference for the speedup column (same
        # transport, default knobs), so each cell's parallel_speedup
        # isolates the dispatch scaling from the transport's base cost
        base = bench_dwork(n, 1, steal_n=4, shards=1,
                           transport=transport,
                           repeats=reps)["tasks_per_s"]
        for shards in (1, 2, 4):
            for steal_n in (1, 4, 8):
                r = bench_dwork(n, workers, steal_n=steal_n,
                                shards=shards, transport=transport,
                                repeats=reps)
                cell = {
                    "transport": transport, "shards": shards,
                    "steal_n": steal_n,
                    "tasks_per_s": r["tasks_per_s"],
                    "parallel_speedup": round(
                        r["tasks_per_s"] / base, 3) if base else None,
                    "per_task_overhead_us": r["per_task_overhead_us"],
                    "rpc_per_task_us": r["rpc_per_task_us"],
                }
                if "rpc_hops" in r:
                    cell["rpc_hops"] = r["rpc_hops"]
                out["cells"].append(cell)
    return out


def run_check() -> int:
    """CI perf gate: re-measure dwork and fail (exit 1) if per-task
    overhead regressed more than CHECK_TOLERANCE vs the committed
    baseline.  Both sides are best-of-repeats (bench_dwork), so one
    noisy CI scheduling hiccup can't fail the build."""
    baseline = json.loads(BASELINE.read_text())
    committed = baseline["schedulers"]["dwork"]
    _warmup()
    # absolute microseconds don't transfer across machines: scale the
    # committed limits by the calibration-loop ratio (>= 1 only — a
    # faster machine must still beat the baseline, and the relaxation is
    # capped so a broken calibration can't grant unlimited slack)
    scale = 1.0
    base_cal = baseline.get("calibration_us")
    if base_cal:
        scale = min(max(_calibrate_us() / base_cal, 1.0), 4.0)
    print(f"machine-speed scale vs baseline: {scale:.2f}x")
    failures = []
    for w in WORKER_COUNTS:
        base = committed[f"workers={w}"]["per_task_overhead_us"]
        limit = base * CHECK_TOLERANCE * scale
        # a regression must reproduce: CPU-throttling bursts on shared
        # runners can span one best-of-5 window, so an over-limit result
        # gets two fresh re-measurements (with a settle pause) and fails
        # only if every attempt exceeds the limit
        best = None
        for attempt in range(3):
            meas = bench_dwork(300, w, repeats=5)["per_task_overhead_us"]
            best = meas if best is None else min(best, meas)
            if best <= limit:
                break
            time.sleep(2)
        status = "OK" if best <= limit else "REGRESSED"
        print(f"dwork workers={w}: {best:.2f}us vs baseline {base:.2f}us "
              f"(limit {limit:.2f}us) {status}")
        if best > limit:
            failures.append(w)
    if failures:
        print(f"perf regression at workers={failures} "
              f"(> {CHECK_TOLERANCE:.0%} of committed BENCH_engine.json)",
              file=sys.stderr)
        return 1
    # instrumentation-overhead cell: attaching the obs registry must not
    # cost the hot path more than the 5% budget.  Self-relative (on vs
    # off measured back-to-back on THIS machine), so no baseline scaling
    # — only the absolute noise floor is machine-scaled.  Same
    # reproduce-to-fail retry policy as the regression cells above.
    floor_us = INSTR_FLOOR_US * scale
    cell = None
    for attempt in range(3):
        cell = bench_instrumentation()
        if cell["on_us"] <= cell["off_us"] * INSTR_TOLERANCE + floor_us:
            break
        time.sleep(2)
    ok = cell["on_us"] <= cell["off_us"] * INSTR_TOLERANCE + floor_us
    print(f"instrumentation: {cell['off_us']:.2f}us bare vs "
          f"{cell['on_us']:.2f}us with metrics (growth {cell['growth']:.3f}, "
          f"limit {INSTR_TOLERANCE:.2f}x + {floor_us:.2f}us) "
          f"{'OK' if ok else 'REGRESSED'}")
    if not ok:
        print(f"instrumentation overhead exceeds the "
              f"{INSTR_TOLERANCE - 1:.0%} budget", file=sys.stderr)
        return 1
    # critical-path analyzer: strictly post-hoc.  The unchanged budget
    # above is the proof the analyzer never touches the dispatch loop
    # (it only ever reads a snapshot of the finished trace); this cell
    # confirms it still produces an explanation from such a run and
    # prices the analysis itself — paid at read time, not dispatch time.
    eng = Engine(workers=4, steal_n=4)
    for i in range(300):
        eng.submit(f"t{i}", meta={"x": i})
    rep = eng.run(lambda name, meta: (True, meta["x"] * 2))
    t0 = time.perf_counter()
    cp = rep.overhead().explain()
    explain_ms = (time.perf_counter() - t0) * 1e3
    if not cp.path or cp.makespan_s <= 0:
        print("critical-path analyzer produced no explanation from a "
              "completed run", file=sys.stderr)
        return 1
    print(f"critical-path analyzer: post-hoc only ({explain_ms:.1f}ms "
          f"for {cp.n_tasks} tasks, {len(cp.path)} on path, "
          f"sched {cp.sched_frac:.1%}) — hot-path budget unchanged")
    # GIL-escape cell: CPU-bound tasks at 4 proc workers vs 1.  The bar
    # is machine-scaled — worker processes cannot outrun the host's
    # cores, so a 2-3 core runner gets a reduced bar and a 1-core
    # runner only enforces the zero-loss half (the SIGKILL drill runs
    # regardless: crash recovery is core-count independent).  Same
    # reproduce-to-fail retry policy as the cells above.
    ncpu = os.cpu_count() or 1
    need = (SPEEDUP_MIN_4CORE if ncpu >= 4
            else SPEEDUP_MIN_2CORE if ncpu >= 2 else None)
    sec = None
    for attempt in range(3):
        sec = bench_proc_cpu()
        ok = (sec["sigkill"]["lost"] == 0
              and (need is None or sec["parallel_speedup"] >= need))
        if ok:
            break
        time.sleep(2)
    sp = sec["parallel_speedup"]
    kill = sec["sigkill"]
    bar = f">= {need:.1f}x required" if need else \
        f"speedup bar skipped ({ncpu} cpu)"
    print(f"proc GIL-escape: {sp:.2f}x tasks/s at 4 proc workers vs 1 "
          f"({ncpu} cpus, {bar}); sigkill drill: {kill['done_ok']}/"
          f"{kill['n_tasks']} done, {kill['lost']} lost, "
          f"{kill['worker_deaths']} worker death(s)")
    if kill["lost"] != 0:
        print(f"SIGKILL drill lost {kill['lost']} task(s) — proc "
              f"requeue-on-crash is broken", file=sys.stderr)
        return 1
    if need is not None and sp < need:
        print(f"CPU-bound proc speedup {sp:.2f}x < {need:.1f}x on a "
              f"{ncpu}-core machine — GIL escape regressed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        sys.exit(run_check())
    quick = "--full" not in sys.argv
    if "--sweep" in sys.argv:
        result = run_sweep(quick=quick)
        SWEEP_OUT.write_text(json.dumps(result, indent=1, default=str))
        print(json.dumps(result, indent=1, default=str))
        print(f"\nwrote {SWEEP_OUT}", file=sys.stderr)
    else:
        result = run(quick=quick)
        BASELINE.write_text(json.dumps(result, indent=1, default=str))
        print(json.dumps(result, indent=1, default=str))
        print(f"\nwrote {BASELINE}", file=sys.stderr)
