"""Fig. 4 reproduction: minimum effective task granularity per scheduler.

Two layers:
  * MEASURED constants for our implementations (dwork in-proc/TCP RTT,
    pmake popen launch cost, mpi-list per-rank jitter sigma);
  * the paper's Summit constants (Table 4) driving the same scaling laws.
The deliverable table: efficiency vs task size per scheduler at the paper's
rank counts, plus the METG crossing (efficiency = 0.5), validated against
the paper's §4 values (0.3 ms / 25 ms / 4.5 s at 864 ranks).
"""
from __future__ import annotations

import math
import subprocess
import sys
import time

import numpy as np

from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.dwork.client import TCPServer, TCPTransport
from repro.core.metg import METGModel, efficiency
from repro.core.mpi_list import Context

RANKS = (6, 60, 864, 6912)
TASK_SIZES = (1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


def measure_dwork_rtt(n: int = 2000) -> dict:
    """Per-task Steal+Complete round-trip (the paper's 23 us analog)."""
    out = {}
    srv = TaskServer()
    cl = Client(InProcTransport(srv), "w")
    for i in range(n):
        cl.create(f"t{i}")
    t0 = time.perf_counter()
    done = cl.run_loop(lambda *_: True, steal_n=1, max_idle=1)
    out["inproc_rtt_s"] = (time.perf_counter() - t0) / max(done, 1)

    srv2 = TaskServer()
    tcp = TCPServer(("127.0.0.1", 0), srv2)
    tcp.serve_background()
    cl2 = Client(TCPTransport(*tcp.server_address), "w")
    n2 = min(n, 500)
    for i in range(n2):
        cl2.create(f"t{i}")
    t0 = time.perf_counter()
    done = cl2.run_loop(lambda *_: True, steal_n=1, max_idle=1)
    out["tcp_rtt_s"] = (time.perf_counter() - t0) / max(done, 1) / 2.0
    tcp.shutdown()
    return out


def measure_pmake_launch(n: int = 15) -> float:
    """popen launch cost of a no-op shell task (jsrun analog)."""
    t0 = time.perf_counter()
    for _ in range(n):
        subprocess.run(["sh", "-c", "true"], check=True)
    return (time.perf_counter() - t0) / n


def measure_mpilist_sigma(procs: int = 16, n_tasks: int = 2000) -> float:
    """Per-rank runtime jitter (straggler sigma) of a trivial map."""
    C = Context(procs)
    times = []
    dfm = C.iterates(n_tasks)
    for _ in range(5):
        t_ranks = []
        for blk in dfm.parts:
            t0 = time.perf_counter()
            _ = [x * x for x in blk]
            t_ranks.append(time.perf_counter() - t0)
        times.append(np.std(t_ranks))
    return float(np.mean(times))


def run(quick: bool = True) -> dict:
    model = METGModel.from_paper()
    meas = measure_dwork_rtt(400 if quick else 2000)
    launch = measure_pmake_launch(8 if quick else 30)
    sigma = measure_mpilist_sigma(8, 500 if quick else 4000)

    rows = []
    for ranks in RANKS:
        metg = {
            "pmake_paper": model.pmake_metg(ranks),
            "pmake_measured": launch * (1 + math.log(ranks) / 10) ,
            "dwork_paper": model.dwork_metg(ranks),
            "dwork_measured_inproc": meas["inproc_rtt_s"] * ranks,
            "dwork_measured_tcp": meas["tcp_rtt_s"] * ranks,
            "mpilist_paper": model.mpilist_metg(ranks),
            "mpilist_measured": sigma * math.sqrt(2 * math.log(ranks)),
        }
        effs = {f"eff@{t:g}s": {k: round(efficiency(t, v), 3)
                                for k, v in metg.items()}
                for t in TASK_SIZES}
        rows.append({"ranks": ranks, "metg_s": metg, **effs})

    # paper §4 headline: ordering + magnitudes at 864 ranks
    r864 = rows[2]["metg_s"]
    checks = {
        "ordering_mpilist<dwork<pmake":
            r864["mpilist_paper"] < r864["dwork_paper"] < r864["pmake_paper"],
        "dwork_scales_linearly":
            abs(rows[3]["metg_s"]["dwork_paper"]
                / r864["dwork_paper"] - 6912 / 864) < 1e-6,
        "paper_864_dwork_ms": round(r864["dwork_paper"] * 1e3, 1),
        "paper_864_pmake_s": round(r864["pmake_paper"], 2),
        "measured_dwork_rtt_us": round(meas["inproc_rtt_s"] * 1e6, 1),
        "measured_tcp_rtt_us": round(meas["tcp_rtt_s"] * 1e6, 1),
        "measured_pmake_launch_s": round(launch, 4),
        "measured_mpilist_sigma_s": round(sigma, 6),
    }
    return {"rows": rows, "checks": checks}


def format_table(res: dict) -> str:
    lines = ["| ranks | pmake METG (paper) | dwork METG (paper) | "
             "mpi-list METG (paper) | dwork METG (ours, in-proc) |",
             "|---|---|---|---|---|"]
    for row in res["rows"]:
        m = row["metg_s"]
        lines.append(
            f"| {row['ranks']} | {m['pmake_paper']:.2f} s "
            f"| {m['dwork_paper']*1e3:.1f} ms | {m['mpilist_paper']*1e3:.2f} ms "
            f"| {m['dwork_measured_inproc']*1e3:.2f} ms |")
    return "\n".join(lines)


if __name__ == "__main__":
    import json
    res = run()
    print(format_table(res))
    print(json.dumps(res["checks"], indent=1))
