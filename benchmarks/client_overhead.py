"""Client overhead benchmark: per-future cost of the futures front door
vs the raw engine path, at 1 / 4 / 16 workers, emitted as
BENCH_client.json.

The futures layer adds work per task on both sides of the dispatch
loop — a Future allocation + registration at submit, the exception-
capturing call wrapper at execution, and the first-terminal
notification + condition broadcast at resolution.  This benchmark
keeps that tax honest:

    raw     run_pool over a pre-created TaskServer universe (the
            engine-overhead baseline path, no futures)
    client  the same workload as `Client.submit(...)` -> `gather(...)`
            on the resident engine

Modes:
    (default)   quick run -> BENCH_client.json (+ stdout)
    --full      2000 tasks instead of 400
    --check     re-measure and fail (exit 1) if the client's per-future
                overhead regressed > CHECK_TOLERANCE vs the committed
                BENCH_client.json, or exceeds RATIO_LIMIT x the raw
                engine overhead measured in the SAME run (the
                acceptance bound: client <= 2x raw)
"""
from __future__ import annotations

import gc
import json
import sys
import time
from pathlib import Path

from repro.client import Client
from repro.core.dwork import Client as DworkClient
from repro.core.dwork import InProcTransport, TaskServer, run_pool

# machine-speed probe shared with the engine gate (Python puts this
# script's own directory on sys.path): both gates scale their committed
# limits with ONE estimator
from engine_overhead import _calibrate_us

WORKER_COUNTS = (1, 4, 16)
CHECK_TOLERANCE = 1.25          # CI fails if overhead grows > 25%
RATIO_LIMIT = 2.0               # client must stay <= 2x the raw path
REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "BENCH_client.json"


def _op(x: int) -> int:
    return x * 2


def bench_raw(n_tasks: int, workers: int, steal_n: int = 4,
              repeats: int = 3) -> dict:
    """The engine-overhead path: a universe created on a TaskServer and
    drained by run_pool.  The create phase is folded into the wall (the
    client's span covers ITS creates, so excluding the raw path's would
    bias the ratio against the futures layer).  Best-of-N (hiccups only
    ever ADD time)."""
    best = None
    for _ in range(max(repeats, 1)):
        gc.collect()
        srv = TaskServer()
        boss = DworkClient(InProcTransport(srv), "boss")
        t0 = time.perf_counter()
        for i in range(n_tasks):
            boss.create(f"t{i}", meta={"x": i})
        create_s = time.perf_counter() - t0
        rep = run_pool(srv, lambda name, meta: (True, meta["x"] * 2),
                       workers=workers, steal_n=steal_n)
        ov = rep.overhead()
        wall = ov.wall_s + create_s
        per_task = max(wall * ov.workers - ov.compute_s, 0.0) / n_tasks
        if best is None or per_task < best[0]:
            best = (per_task, n_tasks / wall if wall > 0 else 0.0)
    return {
        "workers": workers,
        "tasks_per_s": round(best[1], 1),
        "per_task_overhead_us": round(best[0] * 1e6, 2),
    }


def bench_client(n_tasks: int, workers: int, steal_n: int = 4,
                 repeats: int = 3) -> dict:
    """The futures path: submit -> Future -> gather on the resident
    engine, per-future overhead measured from the same trace math."""
    best = None
    for _ in range(max(repeats, 1)):
        gc.collect()
        with Client(scheduler="dwork", workers=workers,
                    steal_n=steal_n) as c:
            fs = [c.submit(_op, i) for i in range(n_tasks)]
            vals = c.gather(fs)
            assert vals == [i * 2 for i in range(n_tasks)]
            ov = c.report()
        assert ov.n_tasks == n_tasks
        if best is None or ov.per_task_overhead_s < best.per_task_overhead_s:
            best = ov
    return {
        "workers": workers,
        "futures_per_s": round(best.tasks_per_s, 1),
        "per_future_overhead_us": round(best.per_task_overhead_s * 1e6, 2),
    }


def _warmup():
    bench_raw(100, 1, repeats=1)
    bench_client(100, 1, repeats=1)
    gc.collect()




def run(quick: bool = True) -> dict:
    n = 400 if quick else 2000
    _warmup()
    out = {"n_tasks": n, "calibration_us": round(_calibrate_us(), 1),
           "workers": {}}
    for w in WORKER_COUNTS:
        # both sides best-of-5: a CPU-throttle burst on a shared runner
        # only ever ADDS time, so the minima are the stable estimates
        # and their ratio converges to the intrinsic client tax
        raw = bench_raw(n, w, repeats=5)
        cli = bench_client(n, w, repeats=5)
        ratio = (cli["per_future_overhead_us"]
                 / max(raw["per_task_overhead_us"], 1e-9))
        out["workers"][f"workers={w}"] = {
            "raw": raw, "client": cli,
            "client_vs_raw": round(ratio, 3),
        }
    return out


def run_check() -> int:
    """CI gate: per-future overhead must stay within CHECK_TOLERANCE of
    the committed baseline AND within RATIO_LIMIT x the raw engine path
    measured in the same run.  Over-limit results get two fresh
    re-measurements before failing (shared-runner throttling bursts)."""
    baseline = json.loads(BASELINE.read_text())
    _warmup()
    scale = 1.0
    base_cal = baseline.get("calibration_us")
    if base_cal:
        scale = min(max(_calibrate_us() / base_cal, 1.0), 4.0)
    print(f"machine-speed scale vs baseline: {scale:.2f}x")
    failures = []
    for w in WORKER_COUNTS:
        cell = baseline["workers"][f"workers={w}"]
        base_us = cell["client"]["per_future_overhead_us"]
        limit_us = base_us * CHECK_TOLERANCE * scale
        best_us = best_raw = None
        for attempt in range(3):
            raw = bench_raw(400, w, repeats=5)["per_task_overhead_us"]
            us = bench_client(400, w, repeats=5)["per_future_overhead_us"]
            best_us = us if best_us is None else min(best_us, us)
            best_raw = raw if best_raw is None else min(best_raw, raw)
            # ratio of the two minima: each converges to the intrinsic
            # cost as throttle spikes are filtered, so their quotient is
            # the stable client-tax estimate even on a noisy runner
            if best_us <= limit_us \
                    and best_us / max(best_raw, 1e-9) <= RATIO_LIMIT:
                break
            time.sleep(2)
        best_ratio = best_us / max(best_raw, 1e-9)
        ok = best_us <= limit_us and best_ratio <= RATIO_LIMIT
        print(f"client workers={w}: {best_us:.2f}us/future vs baseline "
              f"{base_us:.2f}us (limit {limit_us:.2f}us), "
              f"{best_ratio:.2f}x raw (limit {RATIO_LIMIT:.1f}x) "
              f"{'OK' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(w)
    if failures:
        print(f"client overhead regression at workers={failures} "
              f"(vs committed BENCH_client.json / {RATIO_LIMIT:.1f}x raw)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        sys.exit(run_check())
    result = run(quick="--full" not in sys.argv)
    BASELINE.write_text(json.dumps(result, indent=1, default=str))
    print(json.dumps(result, indent=1, default=str))
    print(f"\nwrote {BASELINE}", file=sys.stderr)
