"""Benchmark aggregator: one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full]

  metg          Fig. 4  — METG vs task size, three schedulers
  overhead      Table 4 / Fig. 5 — per-component overhead breakdown
  comparison    Table 1 — feature matrix (claims verified in code)
  million_tasks §6 — 1M-task create+deque throughput
  roofline      §Roofline — per-(arch x shape) terms from the dry-run
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from benchmarks import comparison, metg, million_tasks, overhead, roofline

OUT = Path(__file__).resolve().parent / "results"


def main() -> None:
    quick = "--full" not in sys.argv
    results = {}
    for name, mod in [("metg", metg), ("overhead", overhead),
                      ("comparison", comparison),
                      ("million_tasks", million_tasks),
                      ("roofline", roofline)]:
        t0 = time.perf_counter()
        print(f"=== {name} ===", flush=True)
        try:
            res = mod.run(quick=quick)
            results[name] = res
            if name == "metg":
                print(metg.format_table(res))
                print(json.dumps(res["checks"], indent=1))
            elif name == "roofline":
                print(json.dumps(res["summary"], indent=1))
                print(res["table_single_pod"])
            else:
                print(json.dumps(res, indent=1, default=str)[:4000])
        except Exception as e:  # noqa: BLE001
            results[name] = {"error": f"{type(e).__name__}: {e}"}
            print("ERROR:", results[name]["error"])
        print(f"--- {name} done in {time.perf_counter()-t0:.1f}s\n",
              flush=True)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "bench_results.json").write_text(
        json.dumps(results, indent=1, default=str))
    print(f"[benchmarks] wrote {OUT / 'bench_results.json'}")
    errs = [k for k, v in results.items() if "error" in v]
    if errs:
        print("FAILED:", errs)
        sys.exit(1)


if __name__ == "__main__":
    main()
