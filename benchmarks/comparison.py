"""Table 1 reproduction: feature comparison for the three tools — with the
testable claims checked programmatically against this codebase."""
from __future__ import annotations

FEATURES = {
    "pmake": {"target": "modeling", "query": "CLI", "persistence": "file",
              "language": "shell (yaml rules)", "dynamic": "no",
              "push_pull": "push"},
    "dwork": {"target": "modeling", "query": "TCP/CLI", "persistence": "file (TKRZW-analog)",
              "language": "msgpack wire (protobuf-analog)",
              "dynamic": "replace (Transfer)", "push_pull": "pull"},
    "mpi-list": {"target": "datactr", "query": "no", "persistence": "no",
                 "language": "Py", "dynamic": "interactive",
                 "push_pull": "push"},
}


def verify() -> dict:
    """Each Table-1 claim that is checkable in code, checked."""
    checks = {}
    # pmake: file persistence == restart skips completed tasks (tested in
    # tests/test_pmake.py::test_full_run_and_restart)
    from repro.core.pmake import PMake
    checks["pmake_file_sync"] = hasattr(PMake, "run")
    # dwork: persistence + pull + dynamic replace
    from repro.core.dwork import TaskServer
    checks["dwork_persistence"] = hasattr(TaskServer, "save") and \
        hasattr(TaskServer, "load")
    from repro.core.dwork.api import Transfer
    checks["dwork_dynamic_replace"] = Transfer is not None
    # mpi-list: no persistence, interactive
    from repro.core.mpi_list import DFM
    checks["mpilist_no_persistence"] = not hasattr(DFM, "save")
    checks["mpilist_interactive_ops"] = all(
        hasattr(DFM, op) for op in
        ("map", "flatMap", "filter", "reduce", "scan", "collect",
         "repartition", "group"))
    return checks


def run(quick: bool = True) -> dict:
    return {"table1": FEATURES, "verified": verify()}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
