"""Serving latency benchmark: a seeded open-loop arrival process against
the resident engine + METG-batching frontend, emitted as
BENCH_serving.json — the serving-layer companion to BENCH_engine.json.

Open-loop means arrival times are drawn up front (seeded Poisson) and
paced on the wall clock regardless of how fast the server responds, so a
slow server shows up as queue growth and tail latency, not as a politely
slowed-down client.  The run doubles as the subsystem's acceptance demo:
>= 1000 requests served through dynamic batching, one worker killed
mid-stream (seeded FaultPlan), zero requests lost, p50/p95/p99 latency
reported from the trace.

The load is two-tenant mixed: requests alternate between `tenant-a` and
`tenant-b` labels, and the report carries per-tenant p50/p95/p99 in its
`tenants` section (reporting only — groundwork for a fairness gate; the
--check gate still compares the aggregate percentiles).

Modes:
    (default)   quick run -> BENCH_serving.json (+ stdout)
    --full      5000 requests instead of 1000
    --check     re-measure and compare against the committed
                BENCH_serving.json; exits non-zero if p95 latency or
                throughput regressed past tolerance (the CI perf gate)
"""
from __future__ import annotations

import gc
import json
import random
import sys
import time
from pathlib import Path

from repro.core.engine import REQUEUED, Engine, FaultPlan
from repro.core.serving import Frontend

REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "BENCH_serving.json"
WORKERS = 4
MEAN_GAP_S = 150e-6            # ~6.7k req/s offered load
MAX_WAIT_S = 0.002             # frontend deadline (bounds p50 from below)
MAX_BATCH = 32
KILL_AFTER_STEALS = 5          # w1 dies once it has stolen 5 batch tasks
TENANTS = ("tenant-a", "tenant-b")   # mixed load alternates between these
# latency tolerances are looser than the engine-overhead gate (1.25x):
# tail percentiles on a shared runner are far noisier than best-of means
CHECK_P95_TOLERANCE = 2.0
CHECK_THROUGHPUT_TOLERANCE = 2.0


def _calibrate_us() -> float:
    """Machine-speed probe (same estimator as engine_overhead): lets the
    --check gate scale latency limits on slower hardware."""
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        total = 0
        for i in range(100000):
            total += i * i
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run_once(n: int = 1000, *, seed: int = 0, kill: bool = True) -> dict:
    faults = FaultPlan(seed).kill_worker(
        "w1", after_steals=KILL_AFTER_STEALS) if kill else None
    eng = Engine(workers=WORKERS, resident=True, steal_n=4, faults=faults)
    fe = Frontend(eng, lambda ps: [p * 3 + 1 for p in ps],
                  max_queue=4096, max_batch=MAX_BATCH,
                  max_wait_s=MAX_WAIT_S, per_request_s0=2e-6)
    fe.start()
    rng = random.Random(seed)
    gaps = [rng.expovariate(1.0 / MEAN_GAP_S) for _ in range(n)]
    reqs = []
    t0 = time.perf_counter()
    t_next = t0
    for i, gap in enumerate(gaps):
        t_next += gap
        # open-loop pacing; oversleep self-corrects (t_next is absolute)
        # and sleep(0) yields the GIL so pacing can't starve the server
        while True:
            remaining = t_next - time.perf_counter()
            if remaining <= 0:
                break
            time.sleep(remaining if remaining > 1e-3 else 0)
        # two-tenant mixed load: deterministic alternation, so both
        # tenants see the same seeded arrival process interleaved
        reqs.append(fe.submit(i, tenant=TENANTS[i % 2]))
    lost = 0
    for r in reqs:
        if not r.wait(60):
            lost += 1
    wall = time.perf_counter() - t0
    fe.close()
    rep = eng.shutdown()
    bad = sum(1 for i, r in enumerate(reqs)
              if not r.ok or r.value != 3 * i + 1)
    lat = rep.overhead().requests
    requeued = sum(e.extra.get("n", 1) for e in rep.trace.of(REQUEUED))
    out = {
        "n_requests": n,
        "workers": WORKERS,
        "mean_gap_us": MEAN_GAP_S * 1e6,
        "max_wait_ms": MAX_WAIT_S * 1e3,
        "wall_s": round(wall, 4),
        "throughput_rps": round(n / wall, 1),
        "lost": lost,
        "bad_responses": bad,
        "workers_killed": rep.trace.count("worker_dead"),
        "n_requeued": requeued,
        "trace_emitted": rep.trace.n_emitted,
        "trace_dropped": rep.trace.dropped,
        **lat.summary(),
    }
    if lost or bad:
        raise AssertionError(f"request loss/corruption: {out}")
    if sorted(out.get("tenants", ())) != sorted(TENANTS):
        raise AssertionError(f"per-tenant slices missing: {out.keys()}")
    if kill and (out["workers_killed"] != 1 or requeued < 1):
        raise AssertionError(f"injected kill did not bite: {out}")
    return out


def run(n: int = 1000, repeats: int = 3) -> dict:
    """Best-of-N on p95 (hiccups only ever ADD latency); the committed
    baseline and the --check gate use the same estimator."""
    best = None
    for _ in range(max(repeats, 1)):
        gc.collect()
        r = run_once(n)
        if best is None or r["latency_ms"]["p95"] < best["latency_ms"]["p95"]:
            best = r
    best["calibration_us"] = round(_calibrate_us(), 1)
    return best


def run_check() -> int:
    """CI perf gate: fail (exit 1) if serving p95 latency or throughput
    regressed past tolerance vs the committed baseline.  Zero request
    loss is asserted by every run regardless."""
    baseline = json.loads(BASELINE.read_text())
    scale = 1.0
    base_cal = baseline.get("calibration_us")
    if base_cal:
        scale = min(max(_calibrate_us() / base_cal, 1.0), 4.0)
    print(f"machine-speed scale vs baseline: {scale:.2f}x")
    p95_limit = baseline["latency_ms"]["p95"] * CHECK_P95_TOLERANCE * scale
    tp_floor = baseline["throughput_rps"] / (CHECK_THROUGHPUT_TOLERANCE
                                             * scale)
    best_p95, best_tp = None, None
    for attempt in range(3):
        meas = run(baseline["n_requests"], repeats=3)
        p95 = meas["latency_ms"]["p95"]
        tp = meas["throughput_rps"]
        best_p95 = p95 if best_p95 is None else min(best_p95, p95)
        best_tp = tp if best_tp is None else max(best_tp, tp)
        if best_p95 <= p95_limit and best_tp >= tp_floor:
            break
        time.sleep(2)
    ok = best_p95 <= p95_limit and best_tp >= tp_floor
    print(f"serving p95: {best_p95:.3f}ms vs baseline "
          f"{baseline['latency_ms']['p95']:.3f}ms (limit {p95_limit:.3f}ms); "
          f"throughput: {best_tp:.0f} rps (floor {tp_floor:.0f}) "
          f"{'OK' if ok else 'REGRESSED'}")
    if not ok:
        print("serving latency regression vs committed BENCH_serving.json",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        sys.exit(run_check())
    n = 5000 if "--full" in sys.argv else 1000
    result = run(n)
    BASELINE.write_text(json.dumps(result, indent=1))
    print(json.dumps(result, indent=1))
    print(f"\nwrote {BASELINE}", file=sys.stderr)
