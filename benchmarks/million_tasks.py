"""Paper §6 claim: "can create and deque one million tasks in about a
minute".  We measure create+steal+complete throughput on the in-proc server
and report the extrapolated 1M-task time (full 1M run with --full)."""
from __future__ import annotations

import time

from repro.core.dwork import Client, InProcTransport, TaskServer


def run(quick: bool = True, n: int = None) -> dict:
    n = n or (50_000 if quick else 1_000_000)
    srv = TaskServer()
    cl = Client(InProcTransport(srv), "w")
    t0 = time.perf_counter()
    for i in range(n):
        cl.create(f"t{i}")
    t_create = time.perf_counter() - t0
    t0 = time.perf_counter()
    done = cl.run_loop(lambda *_: True, steal_n=64, max_idle=1)
    t_deque = time.perf_counter() - t0
    assert done == n
    total = t_create + t_deque
    return {
        "n_tasks": n,
        "create_s": round(t_create, 2),
        "deque_complete_s": round(t_deque, 2),
        "tasks_per_s": int(n / total),
        "extrapolated_1M_s": round(total * 1_000_000 / n, 1),
        "paper_claim_s": "~60 (one million in about a minute)",
    }


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(quick="--full" not in sys.argv), indent=1))
